//! Monotonic time for the observability layer.
//!
//! [`Stopwatch`] is the one timing primitive: it reads either the real
//! monotonic clock or a [`Clock::mock`] whose "now" is an atomic
//! nanosecond counter tests advance by hand — so duration-dependent
//! logic (histogram recording, span lengths) is testable without
//! sleeping. [`Timer`] is the pre-obs `util::timer::Timer` API kept as
//! a thin veneer over a real-clock stopwatch; `util::Timer` re-exports
//! it so every existing call site keeps compiling unchanged.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A time source: the process monotonic clock, or a mock counter.
#[derive(Clone, Debug, Default)]
pub enum Clock {
    /// `std::time::Instant` — the normal case.
    #[default]
    Real,
    /// Shared nanosecond counter advanced explicitly by tests.
    Mock(Arc<AtomicU64>),
}

impl Clock {
    /// A mock clock starting at t=0 plus the handle that advances it.
    pub fn mock() -> (Clock, MockTime) {
        let t = Arc::new(AtomicU64::new(0));
        (Clock::Mock(t.clone()), MockTime(t))
    }
}

/// Test handle that moves a [`Clock::Mock`] forward.
#[derive(Clone, Debug)]
pub struct MockTime(Arc<AtomicU64>);

impl MockTime {
    /// Advance mock time by `ns` nanoseconds.
    pub fn advance_ns(&self, ns: u64) {
        self.0.fetch_add(ns, Ordering::SeqCst);
    }

    /// Advance mock time by (fractional) seconds.
    pub fn advance_secs(&self, secs: f64) {
        self.advance_ns((secs * 1e9) as u64);
    }

    /// Current mock time in nanoseconds since clock creation.
    pub fn now_ns(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

#[derive(Clone, Debug)]
enum Origin {
    Real(Instant),
    Mock { time: Arc<AtomicU64>, start: u64 },
}

/// Monotonic elapsed-time measurement against either clock.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    origin: Origin,
}

impl Stopwatch {
    /// Start against the real monotonic clock.
    pub fn start() -> Stopwatch {
        Stopwatch { origin: Origin::Real(Instant::now()) }
    }

    /// Start against an explicit clock (mockable).
    pub fn with_clock(clock: &Clock) -> Stopwatch {
        match clock {
            Clock::Real => Stopwatch::start(),
            Clock::Mock(t) => Stopwatch {
                origin: Origin::Mock { time: t.clone(), start: t.load(Ordering::SeqCst) },
            },
        }
    }

    /// Elapsed nanoseconds since start (or last reset).
    pub fn elapsed_ns(&self) -> u64 {
        match &self.origin {
            Origin::Real(at) => at.elapsed().as_nanos().min(u64::MAX as u128) as u64,
            Origin::Mock { time, start } => time.load(Ordering::SeqCst).saturating_sub(*start),
        }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.elapsed_ns() as f64 * 1e-9
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.elapsed_ns() as f64 * 1e-6
    }

    /// Reset the start point to "now" on the same clock.
    pub fn reset(&mut self) {
        match &mut self.origin {
            Origin::Real(at) => *at = Instant::now(),
            Origin::Mock { time, start } => *start = time.load(Ordering::SeqCst),
        }
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Stopwatch::start()
    }
}

/// Wall-clock timer — the historical `util::Timer` API, now a view
/// over a real-clock [`Stopwatch`].
pub struct Timer(Stopwatch);

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer(Stopwatch::start())
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.0.secs()
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.0.millis()
    }

    /// Reset the start point.
    pub fn reset(&mut self) {
        self.0.reset();
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn real_stopwatch_monotone_and_resets() {
        let mut sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
        sw.reset();
        assert!(sw.secs() < 1.0);
    }

    #[test]
    fn mock_clock_advances_only_by_hand() {
        let (clock, time) = Clock::mock();
        let sw = Stopwatch::with_clock(&clock);
        assert_eq!(sw.elapsed_ns(), 0);
        time.advance_ns(1_500);
        assert_eq!(sw.elapsed_ns(), 1_500);
        time.advance_secs(0.25);
        assert_eq!(sw.elapsed_ns(), 1_500 + 250_000_000);
        assert!((sw.secs() - 0.2500015).abs() < 1e-9);

        // A stopwatch started later measures from its own start point.
        let late = Stopwatch::with_clock(&clock);
        assert_eq!(late.elapsed_ns(), 0);
        time.advance_ns(10);
        assert_eq!(late.elapsed_ns(), 10);
    }

    #[test]
    fn mock_stopwatch_reset_rebases() {
        let (clock, time) = Clock::mock();
        let mut sw = Stopwatch::with_clock(&clock);
        time.advance_ns(100);
        assert_eq!(sw.elapsed_ns(), 100);
        sw.reset();
        assert_eq!(sw.elapsed_ns(), 0);
        time.advance_ns(7);
        assert_eq!(sw.elapsed_ns(), 7);
        assert_eq!(time.now_ns(), 107);
    }
}
