//! Lock-free log-bucketed histogram (HDR-style, power-of-two buckets).
//!
//! Values land in bucket `64 - v.leading_zeros()`: bucket 0 holds the
//! value 0 exactly, bucket `i ≥ 1` holds `[2^(i-1), 2^i - 1]`. Every
//! operation is a relaxed atomic, so one histogram can be hammered from
//! many threads with no coordination. Quantiles are reported as the
//! *bounds of the bucket containing the rank*, which by construction
//! bracket the exact order statistic within one bucket width (a factor
//! of 2) — precise enough for latency triage, cheap enough for the
//! serving hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: value 0, plus one bucket per possible highest set bit.
pub const BUCKETS: usize = 65;

/// Concurrent log-bucketed histogram over `u64` samples.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
}

/// What changed in a [`Histogram`] between two [`HistCursor`] reads —
/// the shippable unit for the ring's obs wire and offline merge.
///
/// `buckets`, `count` and `sum` are increments (additive, wrapping for
/// `sum` like the histogram itself); `max`/`min` are the source's
/// current *absolute* extrema, merged idempotently with
/// `fetch_max`/`fetch_min` so re-shipping them is harmless.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistDelta {
    /// `(bucket index, added samples)` for buckets that grew.
    pub buckets: Vec<(u8, u64)>,
    /// Sum increment (wrapping difference of totals).
    pub sum: u64,
    /// Count increment.
    pub count: u64,
    /// Source's all-time max (0 when it never recorded).
    pub max: u64,
    /// Source's all-time min (`u64::MAX` when it never recorded — the
    /// `fetch_min` identity, so absorbing an empty source is a no-op).
    pub min: u64,
}

impl HistDelta {
    /// True when the delta carries no new samples.
    pub fn is_empty(&self) -> bool {
        self.count == 0 && self.buckets.is_empty()
    }
}

/// Last-shipped totals of one histogram, advanced by
/// [`Histogram::delta_since`]. One cursor per (histogram, shipper).
#[derive(Clone, Debug)]
pub struct HistCursor {
    buckets: [u64; BUCKETS],
    sum: u64,
    count: u64,
}

impl Default for HistCursor {
    fn default() -> Self {
        HistCursor {
            buckets: [0; BUCKETS],
            sum: 0,
            count: 0,
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time summary of a [`Histogram`].
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    /// Upper bucket bounds at the 50th/90th/99th percentile ranks,
    /// clamped to the observed max (0 when empty).
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Index of the bucket a value lands in — the inverse of
    /// [`Histogram::bucket_bounds`] (used when rebuilding a histogram
    /// from snapshot `(lo, hi, n)` triples).
    pub fn bucket_index(v: u64) -> usize {
        Self::bucket_of(v)
    }

    /// Inclusive `[lo, hi]` value range of bucket `idx`.
    pub fn bucket_bounds(idx: usize) -> (u64, u64) {
        assert!(idx < BUCKETS, "bucket index {idx} out of range");
        if idx == 0 {
            (0, 0)
        } else if idx == 64 {
            (1u64 << 63, u64::MAX)
        } else {
            (1u64 << (idx - 1), (1u64 << idx) - 1)
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
    }

    /// Record a duration in seconds as integer nanoseconds.
    pub fn record_secs(&self, secs: f64) {
        self.record((secs.max(0.0) * 1e9) as u64);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples (wrapping on overflow).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX && self.count() == 0 {
            0
        } else {
            m
        }
    }

    /// Mean sample (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// `[lo, hi]` bounds of the bucket holding the `q`-quantile order
    /// statistic (rank `max(1, ceil(q·n))`, 1-based). The exact order
    /// statistic of the recorded multiset is guaranteed to lie within
    /// the returned bounds. Returns `(0, 0)` when empty.
    pub fn quantile_bounds(&self, q: f64) -> (u64, u64) {
        let n = self.count();
        if n == 0 {
            return (0, 0);
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_bounds(idx);
            }
        }
        // Racing recorders can make `count` momentarily ahead of the
        // bucket totals; fall back to the top populated bucket.
        let top = (0..BUCKETS)
            .rev()
            .find(|&i| self.buckets[i].load(Ordering::Relaxed) > 0)
            .unwrap_or(0);
        Self::bucket_bounds(top)
    }

    /// Upper quantile bound clamped to the observed max — the single
    /// number reported as "p50"/"p99" in summaries.
    pub fn quantile(&self, q: f64) -> u64 {
        let (lo, hi) = self.quantile_bounds(q);
        // The exact order statistic is ≤ observed max, so clamping the
        // bucket's upper bound tightens the bracket without breaking it.
        hi.min(self.max()).max(lo)
    }

    /// Snapshot count/sum/min/max and p50/p90/p99.
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            sum: self.sum(),
            min: self.min(),
            max: self.max(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
        }
    }

    /// Non-empty buckets as `(lo, hi, count)` triples.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64, u64)> {
        (0..BUCKETS)
            .filter_map(|i| {
                let n = self.buckets[i].load(Ordering::Relaxed);
                if n == 0 {
                    return None;
                }
                let (lo, hi) = Self::bucket_bounds(i);
                Some((lo, hi, n))
            })
            .collect()
    }

    /// What was recorded since `cursor` last saw this histogram; the
    /// cursor advances to the current totals. Concurrent recording is
    /// fine — samples landing mid-read ship with the *next* delta.
    pub fn delta_since(&self, cursor: &mut HistCursor) -> HistDelta {
        let mut buckets = Vec::new();
        for (idx, b) in self.buckets.iter().enumerate() {
            let now = b.load(Ordering::Relaxed);
            let grew = now.saturating_sub(cursor.buckets[idx]);
            if grew > 0 {
                buckets.push((idx as u8, grew));
            }
            cursor.buckets[idx] = now;
        }
        let sum_now = self.sum();
        let count_now = self.count();
        let delta = HistDelta {
            buckets,
            sum: sum_now.wrapping_sub(cursor.sum),
            count: count_now.saturating_sub(cursor.count),
            max: self.max.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
        };
        cursor.sum = sum_now;
        cursor.count = count_now;
        delta
    }

    /// Merge a delta produced by [`Histogram::delta_since`] on another
    /// histogram into this one. Empty deltas are ignored entirely so
    /// their absolute `max`/`min` fields can't perturb the target.
    pub fn absorb(&self, d: &HistDelta) {
        if d.is_empty() {
            return;
        }
        for &(idx, n) in &d.buckets {
            if let Some(b) = self.buckets.get(idx as usize) {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(d.count, Ordering::Relaxed);
        self.sum.fetch_add(d.sum, Ordering::Relaxed);
        self.max.fetch_max(d.max, Ordering::Relaxed);
        self.min.fetch_min(d.min, Ordering::Relaxed);
    }

    /// Zero every bucket and counter.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_partition_u64() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(u64::MAX), 64);
        // bounds invert bucket_of at both edges of every bucket
        for idx in 0..BUCKETS {
            let (lo, hi) = Histogram::bucket_bounds(idx);
            assert_eq!(Histogram::bucket_of(lo), idx);
            assert_eq!(Histogram::bucket_of(hi), idx);
        }
    }

    #[test]
    fn records_track_count_sum_min_max() {
        let h = Histogram::new();
        assert_eq!((h.count(), h.min(), h.max()), (0, 0, 0));
        for v in [5u64, 0, 100, 7] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 112);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 28.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_bounds_bracket_known_sample() {
        let h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        // p50 rank = 50 → exact value 50, in bucket [32, 63]
        let (lo, hi) = h.quantile_bounds(0.50);
        assert!(lo <= 50 && 50 <= hi, "p50 bracket ({lo}, {hi})");
        // p99 rank = 99 → exact value 99, in bucket [64, 127]
        let (lo, hi) = h.quantile_bounds(0.99);
        assert!(lo <= 99 && 99 <= hi, "p99 bracket ({lo}, {hi})");
        // clamped single-number quantile never exceeds the observed max
        assert!(h.quantile(0.99) <= 100);
        assert_eq!(h.quantile(1.0), 100);
    }

    #[test]
    fn reset_clears_everything() {
        let h = Histogram::new();
        h.record(9);
        h.record(1 << 40);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.quantile_bounds(0.5), (0, 0));
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn delta_absorb_replays_exactly_in_batches() {
        let src = Histogram::new();
        let dst = Histogram::new();
        let mut cursor = HistCursor::default();
        for v in [3u64, 0, 17, 1 << 40] {
            src.record(v);
        }
        let d1 = src.delta_since(&mut cursor);
        assert_eq!(d1.count, 4);
        dst.absorb(&d1);
        // nothing new -> empty delta, and absorbing it changes nothing
        let d2 = src.delta_since(&mut cursor);
        assert!(d2.is_empty());
        dst.absorb(&d2);
        // second batch catches up
        src.record(1);
        src.record(u64::MAX);
        dst.absorb(&src.delta_since(&mut cursor));
        assert_eq!(dst.count(), src.count());
        assert_eq!(dst.sum(), src.sum());
        assert_eq!(dst.min(), src.min());
        assert_eq!(dst.max(), src.max());
        assert_eq!(dst.nonzero_buckets(), src.nonzero_buckets());
    }

    #[test]
    fn empty_delta_does_not_perturb_target_extrema() {
        let src = Histogram::new();
        let mut cursor = HistCursor::default();
        src.record(0); // src min/max both 0
        let _shipped = src.delta_since(&mut cursor);
        let stale = src.delta_since(&mut cursor); // empty, but max=0/min=0
        let dst = Histogram::new();
        dst.record(5);
        dst.absorb(&stale);
        assert_eq!((dst.min(), dst.max()), (5, 5));
    }

    #[test]
    fn concurrent_recording_from_eight_threads_loses_nothing() {
        let h = Histogram::new();
        const PER_THREAD: u64 = 10_000;
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        // spread across many buckets
                        h.record((i + 1) << (t % 5));
                    }
                });
            }
        });
        assert_eq!(h.count(), 8 * PER_THREAD);
        let bucket_total: u64 = h.nonzero_buckets().iter().map(|&(_, _, n)| n).sum();
        assert_eq!(bucket_total, 8 * PER_THREAD);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), PER_THREAD << 4);
    }
}
