//! Prometheus text exposition (format 0.0.4) for a [`Registry`].
//!
//! Dependency-free rendering of the registry's live values into the
//! line format scraped by Prometheus: `# HELP`/`# TYPE` headers, then
//! one sample line per counter/gauge and the cumulative
//! `_bucket`/`_sum`/`_count` family per histogram. Metric names pass
//! through [`sanitize`] (dots become underscores); the original name
//! is preserved in the HELP line so dashboards can be mapped back.
//!
//! `_sum`/`_count` come from the histogram's exact atomics — not from
//! bucket arithmetic — so they are precise even though the buckets
//! themselves are power-of-two brackets.

use super::registry::Registry;

/// Rewrite `name` into the Prometheus metric-name alphabet
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`); every illegal character becomes `_`.
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, ch) in name.chars().enumerate() {
        let ok = ch.is_ascii_alphabetic()
            || ch == '_'
            || ch == ':'
            || (i > 0 && ch.is_ascii_digit());
        out.push(if ok { ch } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Format an `f64` the way Prometheus parsers expect (plain decimal;
/// integral values without a trailing `.0`).
fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render the registry's current state as Prometheus exposition text.
pub fn render(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, v) in reg.counters() {
        let n = sanitize(&name);
        out.push_str(&format!(
            "# HELP {n} Counter {name}\n# TYPE {n} counter\n{n} {v}\n"
        ));
    }
    for (name, v) in reg.gauges() {
        let n = sanitize(&name);
        out.push_str(&format!(
            "# HELP {n} Gauge {name}\n# TYPE {n} gauge\n{n} {}\n",
            fmt_f64(v)
        ));
    }
    for (name, h) in reg.hists() {
        let n = sanitize(&name);
        let hh = h.inner();
        out.push_str(&format!(
            "# HELP {n} Histogram {name}\n# TYPE {n} histogram\n"
        ));
        let mut cum = 0u64;
        for (_lo, hi, cnt) in hh.nonzero_buckets() {
            cum += cnt;
            out.push_str(&format!("{n}_bucket{{le=\"{hi}\"}} {cum}\n"));
        }
        // The +Inf bucket equals the total count by definition; under
        // concurrent recording `count` can momentarily trail the
        // bucket sweep, so keep the cumulative series monotone.
        let total = hh.count().max(cum);
        out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {total}\n"));
        out.push_str(&format!("{n}_sum {}\n", hh.sum()));
        out.push_str(&format!("{n}_count {total}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_maps_into_metric_alphabet() {
        assert_eq!(sanitize("ring.wait_ns"), "ring_wait_ns");
        assert_eq!(sanitize("worker0.ring.hops"), "worker0_ring_hops");
        assert_eq!(sanitize("0weird"), "_weird");
        assert_eq!(sanitize(""), "_");
        let s = sanitize("a-b/c d");
        assert!(s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'), "{s}");
    }

    /// Line-format validator: every line of the exposition must be a
    /// comment or `name[{labels}] value`, HELP/TYPE must precede each
    /// family, histogram buckets must be cumulative and end at +Inf
    /// with exactly the `_count` value, and `_count` must equal the
    /// source histogram's exact count.
    #[test]
    fn exposition_passes_line_format_validation() {
        let reg = Registry::new();
        reg.counter("ring.hops").add(12);
        reg.gauge("proc.rss_bytes").set(4096.0);
        reg.gauge("score.ratio").set(0.75);
        let h = reg.hist("serve.latency_ns");
        for v in [1u64, 3, 3, 900, 70_000] {
            h.record(v);
        }
        let text = reg.to_prometheus();

        let mut typed: std::collections::BTreeMap<String, String> = Default::default();
        let mut helped: std::collections::BTreeSet<String> = Default::default();
        let mut bucket_cum: std::collections::BTreeMap<String, u64> = Default::default();
        let mut inf_seen: std::collections::BTreeMap<String, u64> = Default::default();
        let mut counts: std::collections::BTreeMap<String, u64> = Default::default();
        for line in text.lines() {
            assert!(!line.trim().is_empty(), "no blank lines in exposition");
            if let Some(rest) = line.strip_prefix("# HELP ") {
                helped.insert(rest.split_whitespace().next().expect("help name").to_string());
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().expect("type name").to_string();
                let ty = it.next().expect("type kind").to_string();
                assert!(helped.contains(&name), "HELP must precede TYPE for {name}");
                assert!(
                    matches!(ty.as_str(), "counter" | "gauge" | "histogram"),
                    "unknown TYPE {ty}"
                );
                typed.insert(name, ty);
                continue;
            }
            // sample line: name or name{labels}, then a numeric value
            let (name_part, value) = line.rsplit_once(' ').expect("sample line");
            value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in {line:?}"));
            let (name, labels) = match name_part.split_once('{') {
                Some((n, l)) => (n, Some(l.strip_suffix('}').expect("closed label set"))),
                None => (name_part, None),
            };
            assert!(
                name.chars().enumerate().all(|(i, c)| c.is_ascii_alphabetic()
                    || c == '_'
                    || c == ':'
                    || (i > 0 && c.is_ascii_digit())),
                "illegal metric name {name}"
            );
            let family = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .filter(|f| typed.get(*f).map(String::as_str) == Some("histogram"))
                .unwrap_or(name);
            assert!(typed.contains_key(family), "sample before TYPE: {name}");
            if name.ends_with("_bucket") && typed.get(family).map(String::as_str) == Some("histogram") {
                let le = labels
                    .and_then(|l| l.strip_prefix("le=\""))
                    .and_then(|l| l.strip_suffix('"'))
                    .expect("bucket needs le label");
                let v = value.parse::<u64>().expect("integral bucket count");
                let prev = bucket_cum.insert(family.to_string(), v).unwrap_or(0);
                assert!(v >= prev, "bucket series must be cumulative for {family}");
                if le == "+Inf" {
                    inf_seen.insert(family.to_string(), v);
                } else {
                    le.parse::<u64>().expect("finite le bound");
                    assert!(!inf_seen.contains_key(family), "+Inf must come last");
                }
            }
            if let Some(f) = name.strip_suffix("_count") {
                if typed.get(f).map(String::as_str) == Some("histogram") {
                    counts.insert(f.to_string(), value as u64);
                }
            }
        }
        let fam = "serve_latency_ns";
        assert_eq!(typed.get(fam).map(String::as_str), Some("histogram"));
        assert_eq!(inf_seen.get(fam), Some(&5), "+Inf bucket = total count");
        assert_eq!(counts.get(fam), Some(&5), "_count equals exact count");
        assert!(text.contains("ring_hops 12\n"));
        assert!(text.contains(&format!("{fam}_sum {}\n", 1 + 3 + 3 + 900 + 70_000)));
        assert!(text.contains("proc_rss_bytes 4096\n"), "integral gauge prints plain");
        assert!(text.contains("score_ratio 0.75\n"));
    }
}
