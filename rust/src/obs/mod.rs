//! Unified observability: metrics registry, latency histograms, span
//! tracing, leveled logging, and mockable clocks.
//!
//! Three pillars, all dependency-free and explicitly passed (no
//! process globals):
//!
//! * **Metrics** — a [`Registry`] of atomic [`Counter`]s, [`Gauge`]s
//!   and log-bucketed [`Histogram`]s with p50/p90/p99/max summaries.
//!   Subsystems keep their own handles embedded in hot structs (the
//!   score cache's hit counter, the counting core's path counters) and
//!   register those same handles by name, so a snapshot reads live
//!   values. Serving exposes the snapshot over the wire as
//!   `{"type":"stats"}`.
//! * **Tracing** — a [`Tracer`] of begin/end spans in per-thread
//!   buffers, exported as Chrome trace-event JSON
//!   ([`trace::spans_to_chrome_json`]) that loads in Perfetto: ring
//!   hops (wait → fuse → GES → send), coordinator stages, jointree
//!   collect/distribute, and server request handling each get a lane.
//!   Disabled cost is one relaxed atomic load, pinned by a bench-style
//!   test below.
//! * **Clock & log** — [`clock::Stopwatch`] with a mock-time hook (the
//!   old `util::Timer` is now a view over it), and [`log`] with a
//!   `CGES_LOG=error|warn|info|debug` filter (case-insensitive, warns
//!   once on garbage).
//!
//! The *distributed* half builds on the same types: [`sync`] measures
//! NTP-style clock offsets between wire peers, [`registry`] ships
//! [`RegistryDelta`]s through [`RegistryCursor`]s (merged back with
//! `absorb_prefixed`), [`prometheus`] renders any registry as
//! Prometheus exposition text, [`sysinfo`]'s [`SysSampler`] feeds
//! `/proc/self` gauges, and [`merge`] joins detached per-process
//! artifacts offline. The ring transport carries the deltas and span
//! batches between processes (`coordinator::transport`).

pub mod clock;
pub mod hist;
pub mod log;
pub mod merge;
pub mod prometheus;
pub mod registry;
pub mod sync;
pub mod sysinfo;
pub mod trace;

pub use clock::{Clock, MockTime, Stopwatch, Timer};
pub use hist::{HistCursor, HistDelta, HistSummary, Histogram};
pub use registry::{Counter, Gauge, Hist, Registry, RegistryCursor, RegistryDelta};
pub use sync::ClockOffset;
pub use sysinfo::SysSampler;
pub use trace::{secs_to_ns, SpanRec, TraceHandle, Tracer, COORDINATOR_TID};

#[cfg(test)]
mod tests {
    use super::*;

    /// Bench-style pin on the disabled tracing path: a million
    /// `start()` probes against a disabled tracer must stay within a
    /// generous wall-clock bound (they are one relaxed atomic load
    /// each; the bound leaves ~2µs per probe for the slowest CI box —
    /// a mutex, clock read, or allocation on this path would blow it).
    #[test]
    fn disabled_trace_probe_stays_near_zero_cost() {
        let tr = Tracer::disabled();
        let th = tr.handle(0);
        let sw = Stopwatch::start();
        let mut armed = 0u32;
        for _ in 0..1_000_000u32 {
            if std::hint::black_box(th.start()).is_some() {
                armed += 1;
            }
        }
        let secs = sw.secs();
        assert_eq!(armed, 0);
        assert_eq!(tr.span_count(), 0);
        assert!(secs < 2.0, "1M disabled trace probes took {secs:.3}s — disabled path regressed");
    }

    #[test]
    fn registry_and_tracer_compose_for_a_tiny_run() {
        let reg = Registry::new();
        let tr = Tracer::new(true);
        let lat = reg.hist("demo.latency_ns");
        let mut th = tr.handle(0);
        for i in 0..10u64 {
            let t0 = th.start();
            lat.record(100 + i);
            th.end_args(t0, "op", "demo", &[("i", i as f64)]);
        }
        th.flush();
        assert_eq!(tr.span_count(), 10);
        assert_eq!(lat.inner().count(), 10);
        let json = tr.chrome_json();
        assert!(json.contains("\"ph\":\"B\"") && json.contains("\"ph\":\"E\""));
    }
}
