//! Lock-cheap span tracing with Chrome trace-event export.
//!
//! A [`Tracer`] is shared (cheap `Arc` clone) by every thread in a run;
//! each thread takes a [`TraceHandle`] with its own lane id (`tid`) and
//! buffers spans locally, flushing to the shared sink in batches and on
//! drop — the hot path never takes the sink lock per span. The disabled
//! path is one relaxed atomic load ([`TraceHandle::start`] returns
//! `None` and every `end` is a no-op), pinned by a bench-style test in
//! the obs module.
//!
//! [`spans_to_chrome_json`] renders spans as Chrome trace-event JSON
//! (`ph:"B"`/`"E"` pairs) loadable in `chrome://tracing` and
//! [Perfetto](https://ui.perfetto.dev): one lane per `tid`, with span
//! `args` attached to the begin event. Spans within one lane must be
//! sequential or properly nested — guaranteed when each thread writes
//! through its own handle; the emitter additionally clamps timestamps
//! monotonically per lane so a malformed stream still loads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::infer::json::Json;

/// Lane id used for coordinator-level stage spans (partition, ring,
/// fine-tune), far above any worker index.
pub const COORDINATOR_TID: u32 = 1_000;

/// Handle-local buffer size before a batch flush to the shared sink.
const FLUSH_EVERY: usize = 256;

/// One completed span.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRec {
    pub name: String,
    /// Category ("ring", "stage", "serve", "jointree", ...).
    pub cat: &'static str,
    /// Lane: worker index, server thread index, or [`COORDINATOR_TID`].
    pub tid: u32,
    /// Nanoseconds since the tracer's epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Numeric arguments shown in the trace viewer.
    pub args: Vec<(&'static str, f64)>,
}

#[derive(Debug)]
struct Shared {
    enabled: AtomicBool,
    epoch: Instant,
    sink: Mutex<Vec<SpanRec>>,
}

/// Shared span recorder; clone freely, one per run.
#[derive(Debug, Clone)]
pub struct Tracer {
    shared: Arc<Shared>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::disabled()
    }
}

impl Tracer {
    /// New tracer, recording iff `enabled`.
    pub fn new(enabled: bool) -> Tracer {
        Tracer {
            shared: Arc::new(Shared {
                enabled: AtomicBool::new(enabled),
                epoch: Instant::now(),
                sink: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Tracer that records nothing (the default).
    pub fn disabled() -> Tracer {
        Tracer::new(false)
    }

    /// Is recording on?
    pub fn enabled(&self) -> bool {
        self.shared.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on or off (affects all handles immediately).
    pub fn set_enabled(&self, on: bool) {
        self.shared.enabled.store(on, Ordering::Relaxed);
    }

    /// Nanoseconds since this tracer was created.
    pub fn now_ns(&self) -> u64 {
        self.shared.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// Exact signed offset mapping this tracer's timestamps onto
    /// `other`'s clock: `t_other = t_self + offset`. Both epochs are
    /// in-process [`Instant`]s, so this is the zero-error analogue of
    /// the wire handshake in [`super::sync`] — used for workers that
    /// share the coordinator's process.
    pub fn offset_to(&self, other: &Tracer) -> i64 {
        match self.shared.epoch.checked_duration_since(other.shared.epoch) {
            Some(ahead) => ahead.as_nanos().min(i64::MAX as u128) as i64,
            None => {
                let behind = other.shared.epoch.duration_since(self.shared.epoch);
                -(behind.as_nanos().min(i64::MAX as u128) as i64)
            }
        }
    }

    /// A per-thread recording handle for lane `tid`.
    pub fn handle(&self, tid: u32) -> TraceHandle {
        TraceHandle { shared: self.shared.clone(), tid, buf: Vec::new() }
    }

    /// Spans flushed to the sink so far (handles flush on drop).
    pub fn span_count(&self) -> usize {
        self.shared.sink.lock().expect("trace sink poisoned").len()
    }

    /// Copy of all flushed spans.
    pub fn spans(&self) -> Vec<SpanRec> {
        self.shared.sink.lock().expect("trace sink poisoned").clone()
    }

    /// Drain all flushed spans out of the sink.
    pub fn take_spans(&self) -> Vec<SpanRec> {
        std::mem::take(&mut *self.shared.sink.lock().expect("trace sink poisoned"))
    }

    /// Chrome trace-event JSON of all flushed spans; empty string when
    /// no spans were recorded (a disabled tracer emits zero bytes).
    pub fn chrome_json(&self) -> String {
        spans_to_chrome_json(&self.spans())
    }

    /// Write [`Tracer::chrome_json`] to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.chrome_json())
    }
}

/// Per-thread span recorder; flushes its buffer on drop.
#[derive(Debug)]
pub struct TraceHandle {
    shared: Arc<Shared>,
    tid: u32,
    buf: Vec<SpanRec>,
}

impl TraceHandle {
    /// Begin a span: `Some(start_ns)` when tracing is on, else `None`.
    /// The disabled path is exactly one relaxed atomic load.
    #[inline]
    pub fn start(&self) -> Option<u64> {
        if self.shared.enabled.load(Ordering::Relaxed) {
            Some(self.shared.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64)
        } else {
            None
        }
    }

    /// Current time on the tracer clock (for hand-built spans).
    pub fn now_ns(&self) -> u64 {
        self.shared.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// End a span begun by [`TraceHandle::start`]; no-op when `started`
    /// is `None`.
    #[inline]
    pub fn end(&mut self, started: Option<u64>, name: &str, cat: &'static str) {
        self.end_args(started, name, cat, &[]);
    }

    /// [`TraceHandle::end`] with viewer-visible numeric arguments.
    pub fn end_args(
        &mut self,
        started: Option<u64>,
        name: &str,
        cat: &'static str,
        args: &[(&'static str, f64)],
    ) {
        let Some(start_ns) = started else { return };
        let now = self.now_ns();
        self.push(SpanRec {
            name: name.to_string(),
            cat,
            tid: self.tid,
            start_ns,
            dur_ns: now.saturating_sub(start_ns),
            args: args.to_vec(),
        });
    }

    /// Record a span with explicit timing (e.g. reconstructed from a
    /// transport's own wait/codec measurement). No-op when disabled.
    pub fn add(
        &mut self,
        name: &str,
        cat: &'static str,
        start_ns: u64,
        dur_ns: u64,
        args: &[(&'static str, f64)],
    ) {
        if !self.shared.enabled.load(Ordering::Relaxed) {
            return;
        }
        self.push(SpanRec {
            name: name.to_string(),
            cat,
            tid: self.tid,
            start_ns,
            dur_ns,
            args: args.to_vec(),
        });
    }

    fn push(&mut self, span: SpanRec) {
        self.buf.push(span);
        if self.buf.len() >= FLUSH_EVERY {
            self.flush();
        }
    }

    /// Move buffered spans into the shared sink.
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.shared.sink.lock().expect("trace sink poisoned").append(&mut self.buf);
    }
}

impl Drop for TraceHandle {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Convert seconds to the nanosecond span unit.
pub fn secs_to_ns(secs: f64) -> u64 {
    (secs.max(0.0) * 1e9) as u64
}

/// Render spans as a Chrome trace-event JSON array (`ph:"B"`/`"E"`
/// pairs, timestamps in microseconds), one lane per `tid`. Returns an
/// empty string for an empty span list.
pub fn spans_to_chrome_json(spans: &[SpanRec]) -> String {
    if spans.is_empty() {
        return String::new();
    }
    let mut by_tid: BTreeMap<u32, Vec<&SpanRec>> = BTreeMap::new();
    for s in spans {
        by_tid.entry(s.tid).or_default().push(s);
    }
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() * 2);
    for (tid, mut lane) in by_tid {
        // Same start: the longer span is the outer one and must begin
        // first for stack pairing to nest correctly.
        lane.sort_by(|a, b| a.start_ns.cmp(&b.start_ns).then(b.dur_ns.cmp(&a.dur_ns)));
        let mut stack: Vec<&SpanRec> = Vec::new();
        // Monotonic per-lane cursor: emitted timestamps never go
        // backwards even if the input spans weren't perfectly nested.
        let mut cursor_ns = 0u64;
        let mut emit =
            |events: &mut Vec<Json>, cursor_ns: &mut u64, ph: &str, s: &SpanRec, ts_ns: u64| {
                let ts_ns = ts_ns.max(*cursor_ns);
                *cursor_ns = ts_ns;
                let mut obj = vec![
                    ("name".to_string(), Json::Str(s.name.clone())),
                    ("cat".to_string(), Json::Str(s.cat.to_string())),
                    ("ph".to_string(), Json::Str(ph.to_string())),
                    ("ts".to_string(), Json::Num(ts_ns as f64 / 1e3)),
                    ("pid".to_string(), Json::Num(0.0)),
                    ("tid".to_string(), Json::Num(tid as f64)),
                ];
                if ph == "B" && !s.args.is_empty() {
                    let args = s
                        .args
                        .iter()
                        .map(|&(k, v)| (k.to_string(), Json::Num(v)))
                        .collect::<Vec<_>>();
                    obj.push(("args".to_string(), Json::Obj(args)));
                }
                events.push(Json::Obj(obj));
            };
        for s in lane {
            while let Some(&top) = stack.last() {
                if top.start_ns.saturating_add(top.dur_ns) <= s.start_ns {
                    emit(&mut events, &mut cursor_ns, "E", top, top.start_ns + top.dur_ns);
                    stack.pop();
                } else {
                    break;
                }
            }
            emit(&mut events, &mut cursor_ns, "B", s, s.start_ns);
            stack.push(s);
        }
        while let Some(top) = stack.pop() {
            emit(&mut events, &mut cursor_ns, "E", top, top.start_ns.saturating_add(top.dur_ns));
        }
    }
    let mut out = String::from("[\n");
    for (i, e) in events.iter().enumerate() {
        out.push_str(&e.to_string());
        if i + 1 < events.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_and_emits_nothing() {
        let tr = Tracer::disabled();
        let mut th = tr.handle(3);
        let t0 = th.start();
        assert_eq!(t0, None);
        th.end(t0, "x", "test");
        th.add("y", "test", 0, 10, &[]);
        th.flush();
        assert_eq!(tr.span_count(), 0);
        assert_eq!(tr.chrome_json(), "");
    }

    #[test]
    fn enabled_tracer_captures_spans_with_args() {
        let tr = Tracer::new(true);
        {
            let mut th = tr.handle(1);
            let t0 = th.start();
            assert!(t0.is_some());
            th.end_args(t0, "work", "test", &[("round", 2.0)]);
            // buffered until flush/drop
        }
        let spans = tr.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "work");
        assert_eq!(spans[0].tid, 1);
        assert_eq!(spans[0].args, vec![("round", 2.0)]);
    }

    #[test]
    fn set_enabled_flips_all_handles() {
        let tr = Tracer::disabled();
        let mut th = tr.handle(0);
        assert!(th.start().is_none());
        tr.set_enabled(true);
        let t0 = th.start();
        assert!(t0.is_some());
        th.end(t0, "late", "test");
        th.flush();
        assert_eq!(tr.span_count(), 1);
    }

    #[test]
    fn chrome_export_pairs_and_orders_events() {
        // Two lanes: lane 0 has nested spans, lane 7 sequential ones.
        let spans = vec![
            SpanRec {
                name: "outer".into(),
                cat: "t",
                tid: 0,
                start_ns: 1_000,
                dur_ns: 9_000,
                args: vec![("round", 0.0)],
            },
            SpanRec {
                name: "inner".into(),
                cat: "t",
                tid: 0,
                start_ns: 2_000,
                dur_ns: 3_000,
                args: vec![],
            },
            SpanRec { name: "a".into(), cat: "t", tid: 7, start_ns: 0, dur_ns: 100, args: vec![] },
            SpanRec {
                name: "b".into(),
                cat: "t",
                tid: 7,
                start_ns: 200,
                dur_ns: 50,
                args: vec![],
            },
        ];
        let text = spans_to_chrome_json(&spans);
        let doc = Json::parse(&text).expect("chrome export must parse");
        let events = doc.as_array().expect("array of events");
        assert_eq!(events.len(), 8);
        // per-tid: B/E balance, monotonic ts, matched names via stack
        for tid in [0.0, 7.0] {
            let mut stack: Vec<&str> = Vec::new();
            let mut last_ts = f64::NEG_INFINITY;
            for e in events.iter().filter(|e| e.get("tid").and_then(Json::as_f64) == Some(tid)) {
                let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                assert!(ts >= last_ts, "timestamps regress in lane {tid}");
                last_ts = ts;
                let name = e.get("name").and_then(Json::as_str).unwrap();
                match e.get("ph").and_then(Json::as_str).unwrap() {
                    "B" => stack.push(name),
                    "E" => assert_eq!(stack.pop(), Some(name), "mismatched end in lane {tid}"),
                    other => panic!("unexpected phase {other}"),
                }
            }
            assert!(stack.is_empty(), "unclosed spans in lane {tid}");
        }
        // args survive on the begin event
        let outer_b = events
            .iter()
            .find(|e| {
                e.get("name").and_then(Json::as_str) == Some("outer")
                    && e.get("ph").and_then(Json::as_str) == Some("B")
            })
            .unwrap();
        assert_eq!(
            outer_b.get("args").and_then(|a| a.get("round")).and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn offset_to_is_antisymmetric_and_maps_clocks() {
        let early = Tracer::new(true);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let late = Tracer::new(true);
        // `late`'s epoch is after `early`'s, so a timestamp on `late`'s
        // clock maps to a *larger* value on `early`'s clock.
        let off = late.offset_to(&early);
        assert!(off > 0, "late->early offset must be positive: {off}");
        assert_eq!(early.offset_to(&late), -off);
        // The mapped "now" of one clock lands near the other's now.
        let mapped = late.now_ns().saturating_add_signed(off);
        let err = mapped.abs_diff(early.now_ns());
        assert!(err < 1_000_000_000, "mapped now off by {err} ns");
    }

    #[test]
    fn handle_batches_flush_to_sink() {
        let tr = Tracer::new(true);
        let mut th = tr.handle(0);
        for i in 0..(FLUSH_EVERY + 10) {
            th.add("s", "test", i as u64 * 10, 5, &[]);
        }
        // one batch auto-flushed, remainder still buffered
        assert_eq!(tr.span_count(), FLUSH_EVERY);
        drop(th);
        assert_eq!(tr.span_count(), FLUSH_EVERY + 10);
    }
}
