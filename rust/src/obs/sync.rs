//! NTP-style clock-offset estimation between two stream peers.
//!
//! Ring workers in separate processes stamp spans against independent
//! monotonic epochs ([`super::trace::Tracer`] starts its clock at
//! construction), so merging their traces onto one timeline needs the
//! offset between each pair of clocks. The classic midpoint estimate
//! over a few ping round-trips is plenty here: loopback RTTs are tens
//! of microseconds while ring rounds are milliseconds, so even the
//! worst single-sample error is invisible at trace resolution.
//!
//! Protocol (all messages are 8-byte little-endian `u64` nanosecond
//! timestamps):
//!
//! 1. the **initiator** notes `t1` on its clock and sends it;
//! 2. the **responder** replies with `t_r`, the time on *its* clock;
//! 3. the initiator notes the arrival time `t2` and estimates the
//!    offset mapping responder timestamps onto its own clock as
//!    `(t1 + t2) / 2 - t_r` — exact when the two directions of the
//!    trip are symmetric, off by at most RTT/2 otherwise.
//!
//! [`SYNC_ROUNDS`] trips are made and the estimate from the
//! minimum-RTT trip wins (the trip least likely to have been delayed
//! asymmetrically by scheduling).

use std::io::{Read, Write};

use anyhow::{Context, Result};

/// Ping round-trips per measurement; the minimum-RTT sample wins.
pub const SYNC_ROUNDS: usize = 8;

/// A measured clock relationship between two peers.
///
/// `offset_ns` maps timestamps on the *responder's* clock onto the
/// *initiator's* clock: `t_initiator ≈ t_responder + offset_ns`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClockOffset {
    /// Signed correction to add to responder timestamps.
    pub offset_ns: i64,
    /// Round-trip time of the winning sample — an error bound on the
    /// offset (the true offset is within ±`rtt_ns / 2`).
    pub rtt_ns: u64,
}

impl ClockOffset {
    /// Rebase a responder-clock timestamp onto the initiator's clock,
    /// saturating at the `u64` range ends.
    pub fn apply(&self, ts_ns: u64) -> u64 {
        ts_ns.saturating_add_signed(self.offset_ns)
    }
}

/// A `Read + Write` view stitched from two halves — used when one
/// socket is owned as a buffered reader on one side and a raw clone
/// on the other (full-duplex TCP ring links).
pub struct ReadWritePair<'a, R: Read, W: Write> {
    /// Receiving half.
    pub r: &'a mut R,
    /// Sending half.
    pub w: &'a mut W,
}

impl<R: Read, W: Write> Read for ReadWritePair<'_, R, W> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.r.read(buf)
    }
}

impl<R: Read, W: Write> Write for ReadWritePair<'_, R, W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.w.write(buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

fn send_ts<S: Write + ?Sized>(stream: &mut S, ts: u64) -> Result<()> {
    stream
        .write_all(&ts.to_le_bytes())
        .and_then(|()| stream.flush())
        .context("clock sync: send timestamp")
}

fn recv_ts<S: Read + ?Sized>(stream: &mut S) -> Result<u64> {
    let mut buf = [0u8; 8];
    stream
        .read_exact(&mut buf)
        .context("clock sync: recv timestamp")?;
    Ok(u64::from_le_bytes(buf))
}

/// Initiator side: run `rounds` ping trips against a peer executing
/// [`answer_pings`] with the same `rounds`, reading the local clock
/// through `now_ns`. Returns the minimum-RTT offset estimate.
pub fn measure_offset<S: Read + Write>(
    stream: &mut S,
    now_ns: &mut dyn FnMut() -> u64,
    rounds: usize,
) -> Result<ClockOffset> {
    let mut best = ClockOffset {
        offset_ns: 0,
        rtt_ns: u64::MAX,
    };
    for _ in 0..rounds.max(1) {
        let t1 = now_ns();
        send_ts(stream, t1)?;
        let t_r = recv_ts(stream)?;
        let t2 = now_ns();
        let rtt = t2.saturating_sub(t1);
        if rtt < best.rtt_ns {
            // Midpoint in i128: (t1 + t2) / 2 can exceed u64.
            let mid = (t1 as i128 + t2 as i128) / 2;
            best = ClockOffset {
                offset_ns: (mid - t_r as i128).clamp(i64::MIN as i128, i64::MAX as i128) as i64,
                rtt_ns: rtt,
            };
        }
    }
    Ok(best)
}

/// Responder side: answer `rounds` pings, stamping each reply with the
/// local clock through `now_ns`. The incoming timestamp is only read
/// to pace the exchange; its value is the initiator's business.
pub fn answer_pings<S: Read + Write>(
    stream: &mut S,
    now_ns: &mut dyn FnMut() -> u64,
    rounds: usize,
) -> Result<()> {
    for _ in 0..rounds.max(1) {
        let _t1 = recv_ts(stream)?;
        send_ts(stream, now_ns())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::time::Instant;

    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        (client, server)
    }

    #[test]
    fn measures_known_skew_within_rtt() {
        // Two clocks off the same Instant with a fixed 5 s skew: the
        // initiator's clock runs 5 s ahead of the responder's, so the
        // measured offset (responder -> initiator) should be ~ +5 s.
        const SKEW_NS: u64 = 5_000_000_000;
        let epoch = Instant::now();
        let (mut a, mut b) = socket_pair();

        let responder = std::thread::spawn(move || {
            let mut now = || epoch.elapsed().as_nanos() as u64;
            answer_pings(&mut b, &mut now, SYNC_ROUNDS).expect("responder");
        });
        let mut now = || epoch.elapsed().as_nanos() as u64 + SKEW_NS;
        let off = measure_offset(&mut a, &mut now, SYNC_ROUNDS).expect("initiator");
        responder.join().expect("join");

        assert!(off.rtt_ns < 1_000_000_000, "loopback rtt: {}", off.rtt_ns);
        let err = (off.offset_ns - SKEW_NS as i64).unsigned_abs();
        assert!(
            err <= off.rtt_ns / 2 + 1,
            "offset {} vs skew {SKEW_NS}, rtt {}",
            off.offset_ns,
            off.rtt_ns
        );
    }

    #[test]
    fn negative_skew_is_negative_offset() {
        // Responder ahead of initiator: offset must come out negative.
        const SKEW_NS: u64 = 3_000_000_000;
        let epoch = Instant::now();
        let (mut a, mut b) = socket_pair();

        let responder = std::thread::spawn(move || {
            let mut now = || epoch.elapsed().as_nanos() as u64 + SKEW_NS;
            answer_pings(&mut b, &mut now, SYNC_ROUNDS).expect("responder");
        });
        let mut now = || epoch.elapsed().as_nanos() as u64;
        let off = measure_offset(&mut a, &mut now, SYNC_ROUNDS).expect("initiator");
        responder.join().expect("join");

        assert!(off.offset_ns < 0, "expected negative offset: {off:?}");
        let err = (off.offset_ns + SKEW_NS as i64).unsigned_abs();
        assert!(err <= off.rtt_ns / 2 + 1, "err {err}, rtt {}", off.rtt_ns);
    }

    #[test]
    fn apply_saturates_at_range_ends() {
        let ahead = ClockOffset {
            offset_ns: 10,
            rtt_ns: 0,
        };
        assert_eq!(ahead.apply(u64::MAX - 3), u64::MAX);
        let behind = ClockOffset {
            offset_ns: -10,
            rtt_ns: 0,
        };
        assert_eq!(behind.apply(3), 0);
        assert_eq!(behind.apply(25), 15);
    }
}
