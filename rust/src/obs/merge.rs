//! Offline merge of per-process obs artifacts (`cges obs merge`).
//!
//! The ring's obs wire merges traces and metrics *live*; this module
//! is the escape hatch for workers that ran detached — each process
//! left behind its own `*.trace.json` (Chrome trace array) and/or
//! `*.metrics.json` (registry snapshot). `merge_files` classifies
//! each input by content, not filename:
//!
//! - a JSON **array** is a Chrome trace; its events keep their lanes
//!   but are moved to a distinct `pid` per input, so viewers show one
//!   process group per source file. No clock alignment is attempted —
//!   offline there is no handshake to measure offsets with, and
//!   faking one would be worse than showing honest per-process
//!   timelines side by side.
//! - a JSON **object** with `counters`/`gauges`/`histograms` is a
//!   registry snapshot; it is replayed into one merged [`Registry`].
//!   With a single metrics input names are kept as-is; with several,
//!   each input's series land under a `proc<j>.` prefix to avoid
//!   collisions.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::registry::Registry;
use crate::infer::json::Json;

enum Kind {
    Trace(Vec<Json>),
    Metrics(Json),
}

fn classify(path: &Path) -> Result<Kind> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read obs artifact {}", path.display()))?;
    if text.trim().is_empty() {
        // A disabled tracer writes zero bytes; treat as an empty trace.
        return Ok(Kind::Trace(Vec::new()));
    }
    let v = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
    let is_snapshot = v.get("counters").is_some()
        || v.get("gauges").is_some()
        || v.get("histograms").is_some();
    match v {
        Json::Arr(events) => Ok(Kind::Trace(events)),
        Json::Obj(_) if is_snapshot => Ok(Kind::Metrics(v)),
        _ => bail!(
            "{}: neither a Chrome trace array nor a registry snapshot",
            path.display()
        ),
    }
}

/// Set (or add) the `pid` field of one trace event.
fn set_pid(event: Json, pid: f64) -> Json {
    let Json::Obj(mut fields) = event else {
        return event;
    };
    match fields.iter_mut().find(|(k, _)| k == "pid") {
        Some((_, v)) => *v = Json::Num(pid),
        None => fields.push(("pid".to_string(), Json::Num(pid))),
    }
    Json::Obj(fields)
}

/// Result of [`merge_files`].
pub struct Merged {
    /// Merged trace serialized as a Chrome trace array (empty string
    /// when no trace inputs carried events, matching
    /// [`super::Tracer::chrome_json`]).
    pub trace_json: String,
    /// Merged registry (write via `write_json` / `write_prometheus`).
    pub registry: Registry,
    /// Trace inputs seen.
    pub traces_in: usize,
    /// Metrics inputs seen.
    pub metrics_in: usize,
    /// Total trace events in the merged output.
    pub trace_events: usize,
}

/// Merge obs artifacts (traces and/or metrics snapshots, classified
/// by content) into one trace and one registry.
pub fn merge_files(inputs: &[PathBuf]) -> Result<Merged> {
    if inputs.is_empty() {
        bail!("obs merge needs at least one input file");
    }
    let mut events: Vec<Json> = Vec::new();
    let mut snapshots: Vec<Json> = Vec::new();
    let mut traces_in = 0usize;
    for path in inputs {
        match classify(path)? {
            Kind::Trace(evs) => {
                let pid = traces_in as f64;
                traces_in += 1;
                events.extend(evs.into_iter().map(|e| set_pid(e, pid)));
            }
            Kind::Metrics(snap) => snapshots.push(snap),
        }
    }
    let registry = Registry::new();
    let solo = snapshots.len() == 1;
    for (j, snap) in snapshots.iter().enumerate() {
        let prefix = if solo { String::new() } else { format!("proc{j}.") };
        registry
            .absorb_snapshot(&prefix, snap)
            .with_context(|| format!("merge metrics input {j}"))?;
    }
    let trace_json = if events.is_empty() {
        String::new()
    } else {
        let mut out = String::from("[\n");
        for (i, e) in events.iter().enumerate() {
            out.push_str(&e.to_string());
            if i + 1 < events.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push(']');
        out
    };
    Ok(Merged {
        trace_json,
        registry,
        traces_in,
        metrics_in: snapshots.len(),
        trace_events: events.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tracer;

    fn write_tmp(name: &str, contents: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cges-obs-merge-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("tmp dir");
        let p = dir.join(name);
        std::fs::write(&p, contents).expect("write tmp");
        p
    }

    fn trace_file(name: &str, lane: u32) -> PathBuf {
        let tr = Tracer::new(true);
        let mut th = tr.handle(lane);
        th.add("work", "test", 10, 50, &[("round", 1.0)]);
        th.add("more", "test", 70, 20, &[]);
        th.flush();
        write_tmp(name, &tr.chrome_json())
    }

    #[test]
    fn merges_traces_onto_distinct_pids_and_metrics_with_prefixes() {
        let t0 = trace_file("a.trace.json", 0);
        let t1 = trace_file("b.trace.json", 0);
        let reg_a = Registry::new();
        reg_a.counter("ring.hops").add(4);
        let m0 = write_tmp("a.metrics.json", &reg_a.to_json_string());
        let reg_b = Registry::new();
        reg_b.counter("ring.hops").add(6);
        reg_b.hist("wait_ns").record(123);
        let m1 = write_tmp("b.metrics.json", &reg_b.to_json_string());

        let merged = merge_files(&[t0, m0, t1, m1]).expect("merge");
        assert_eq!((merged.traces_in, merged.metrics_in), (2, 2));

        // Traces: same lane in both inputs, separated by pid.
        let doc = Json::parse(&merged.trace_json).expect("merged trace parses");
        let events = doc.as_array().expect("array");
        assert_eq!(events.len(), merged.trace_events);
        let pids: std::collections::BTreeSet<i64> = events
            .iter()
            .map(|e| e.get("pid").and_then(Json::as_f64).expect("pid") as i64)
            .collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![0, 1]);

        // Metrics: per-input prefixes, values preserved.
        assert_eq!(merged.registry.counter_value("proc0.ring.hops"), Some(4));
        assert_eq!(merged.registry.counter_value("proc1.ring.hops"), Some(6));
        assert_eq!(merged.registry.hist("proc1.wait_ns").inner().count(), 1);
    }

    #[test]
    fn single_metrics_input_keeps_names_and_empty_trace_is_ok() {
        let reg = Registry::new();
        reg.gauge("proc.rss_bytes").set(1.0);
        let m = write_tmp("solo.metrics.json", &reg.to_json_string());
        let empty = write_tmp("off.trace.json", "");
        let merged = merge_files(&[m, empty]).expect("merge");
        assert_eq!(merged.registry.gauge("proc.rss_bytes").get(), 1.0);
        assert_eq!(merged.trace_json, "");

        let junk = write_tmp("junk.json", "{\"not\": \"an artifact\"}");
        assert!(merge_files(&[junk]).is_err());
        assert!(merge_files(&[]).is_err());
    }
}
