//! Low-rate process self-sampler: `/proc/self` into gauges.
//!
//! When `--metrics` is set, `learn`/`serve` start one background
//! thread that periodically reads the process's own resource usage
//! and publishes it as gauges, so metrics snapshots carry the
//! machine-level context next to the algorithmic counters:
//!
//! - `proc.rss_bytes` — resident set size,
//! - `proc.user_secs` / `proc.sys_secs` — cumulative CPU time,
//! - `proc.threads` — live thread count.
//!
//! The reads are Linux-only (`/proc` text files, no syscalls beyond
//! `read`); on other platforms the sampler runs but publishes
//! nothing. Sampling is deliberately coarse (default 500 ms) — this
//! is context, not profiling.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::{Gauge, Registry};

/// Handle to the background sampler thread; dropping it stops and
/// joins the thread.
pub struct SysSampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl SysSampler {
    /// Start sampling into `registry` every `interval`. The first
    /// sample is taken immediately.
    pub fn start(registry: &Registry, interval: Duration) -> SysSampler {
        let stop = Arc::new(AtomicBool::new(false));
        let gauges = Gauges::bind(registry);
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("cges-sysinfo".into())
            .spawn(move || loop {
                gauges.publish();
                // Sleep in short slices so Drop joins promptly.
                let mut waited = Duration::ZERO;
                while waited < interval {
                    if flag.load(Ordering::Relaxed) {
                        return;
                    }
                    let slice = Duration::from_millis(50).min(interval - waited);
                    std::thread::sleep(slice);
                    waited += slice;
                }
                if flag.load(Ordering::Relaxed) {
                    return;
                }
            })
            .expect("spawn sysinfo sampler thread");
        SysSampler {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for SysSampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Take one sample synchronously — used just before a final metrics
/// write so the snapshot reflects end-of-run usage.
pub fn sample_now(registry: &Registry) {
    Gauges::bind(registry).publish();
}

struct Gauges {
    rss: Gauge,
    user: Gauge,
    sys: Gauge,
    threads: Gauge,
}

impl Gauges {
    fn bind(registry: &Registry) -> Gauges {
        Gauges {
            rss: registry.gauge("proc.rss_bytes"),
            user: registry.gauge("proc.user_secs"),
            sys: registry.gauge("proc.sys_secs"),
            threads: registry.gauge("proc.threads"),
        }
    }

    fn publish(&self) {
        if let Some(s) = read_proc_self() {
            self.rss.set(s.rss_bytes);
            self.user.set(s.user_secs);
            self.sys.set(s.sys_secs);
            self.threads.set(s.threads);
        }
    }
}

struct ProcSample {
    rss_bytes: f64,
    user_secs: f64,
    sys_secs: f64,
    threads: f64,
}

#[cfg(target_os = "linux")]
fn read_proc_self() -> Option<ProcSample> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let mut rss_bytes = 0.0;
    let mut threads = 0.0;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: f64 = rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0.0);
            rss_bytes = kb * 1024.0;
        } else if let Some(rest) = line.strip_prefix("Threads:") {
            threads = rest.trim().parse().unwrap_or(0.0);
        }
    }
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // The comm field can contain spaces and parens; everything after
    // the *last* ')' is the fixed-layout tail, where field 3 of the
    // file (state) is tail index 0 → utime (field 14) is index 11 and
    // stime (field 15) is index 12, both in USER_HZ ticks. The /proc
    // ABI fixes USER_HZ at 100 regardless of the kernel tick rate.
    let tail = stat.rsplit_once(')').map(|(_, t)| t)?;
    let fields: Vec<&str> = tail.split_whitespace().collect();
    let ticks = |i: usize| fields.get(i)?.parse::<f64>().ok();
    Some(ProcSample {
        rss_bytes,
        user_secs: ticks(11)? / 100.0,
        sys_secs: ticks(12)? / 100.0,
        threads,
    })
}

#[cfg(not(target_os = "linux"))]
fn read_proc_self() -> Option<ProcSample> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(target_os = "linux")]
    #[test]
    fn sampler_publishes_positive_process_gauges() {
        let reg = Registry::new();
        let sampler = SysSampler::start(&reg, Duration::from_millis(20));
        // Burn a little CPU so user time is nonzero-ish, then let at
        // least one sampling cycle land.
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        assert!(acc != 1); // keep the loop alive
        std::thread::sleep(Duration::from_millis(60));
        drop(sampler); // joins the thread

        assert!(reg.gauge("proc.rss_bytes").get() > 0.0, "rss should be positive");
        assert!(reg.gauge("proc.threads").get() >= 1.0, "at least this thread");
        assert!(reg.gauge("proc.user_secs").get() >= 0.0);
    }

    #[test]
    fn sample_now_is_synchronous_and_safe_everywhere() {
        let reg = Registry::new();
        sample_now(&reg); // must not panic on any platform
        #[cfg(target_os = "linux")]
        assert!(reg.gauge("proc.rss_bytes").get() > 0.0);
    }
}
