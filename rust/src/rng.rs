//! Deterministic PRNG (no `rand` crate in the offline registry).
//!
//! SplitMix64 core with helpers for ranges, floats, shuffling, and the
//! Dirichlet/Gamma sampling needed for random CPT generation
//! (Marsaglia–Tsang for Gamma, Box–Muller for the Gaussian it needs).
//! Everything in the repository that is stochastic (network generation,
//! forward sampling, property tests, benches) goes through this type
//! with explicit seeds so experiments are exactly reproducible.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply rejection-free mapping (Lemire); bias is
        // negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn gen_range_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: first k slots
        for i in 0..k {
            let j = self.gen_range_in(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Johnk boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // G(a) = G(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over `k` categories.
    pub fn dirichlet(&mut self, k: usize, alpha: f64) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = g.iter().sum();
        g.iter_mut().for_each(|x| *x /= s);
        g
    }

    /// Sample a category from a (normalized) probability vector.
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let mut u = self.f64();
        for (i, p) in probs.iter().enumerate() {
            if u < *p {
                return i;
            }
            u -= p;
        }
        probs.len() - 1
    }

    /// Derive an independent child generator (for per-worker seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.gen_range(13);
            assert!(x < 13);
            let y = r.gen_range_in(5, 9);
            assert!((5..9).contains(&y));
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..50_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 50_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(11);
        for &alpha in &[0.3, 1.0, 5.0] {
            let p = r.dirichlet(6, alpha);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(5);
        let probs = [0.2, 0.5, 0.3];
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.categorical(&probs)] += 1;
        }
        for i in 0..3 {
            assert!((counts[i] as f64 / 60_000.0 - probs[i]).abs() < 0.02);
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(9);
        for &shape in &[0.5, 2.0, 7.5] {
            let n = 20_000;
            let m: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() < 0.15 * shape.max(1.0), "shape {shape} mean {m}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
