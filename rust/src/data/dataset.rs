//! Column-major discrete dataset.
//!
//! Variables are `u8` state columns (max cardinality 255 — munin's 21
//! is the largest in the paper's domains). Column-major layout keeps
//! the contingency-counting inner loops (the global hot path) streaming
//! over contiguous memory.

/// Discrete dataset: `n_vars` columns of `n_rows` states each.
#[derive(Clone)]
pub struct Dataset {
    names: Vec<String>,
    cards: Vec<u32>,
    cols: Vec<Vec<u8>>,
    n_rows: usize,
}

impl Dataset {
    /// Build from columns; `cards[i]` must exceed every state in
    /// `cols[i]`.
    pub fn new(names: Vec<String>, cards: Vec<u32>, cols: Vec<Vec<u8>>) -> Self {
        assert_eq!(names.len(), cards.len());
        assert_eq!(names.len(), cols.len());
        let n_rows = cols.first().map(|c| c.len()).unwrap_or(0);
        for (i, col) in cols.iter().enumerate() {
            assert_eq!(col.len(), n_rows, "ragged column {i}");
            debug_assert!(
                col.iter().all(|&s| (s as u32) < cards[i]),
                "state out of range in column {i}"
            );
        }
        Dataset { names, cards, cols, n_rows }
    }

    /// Dataset with default names `X0..X{n-1}`.
    pub fn unnamed(cards: Vec<u32>, cols: Vec<Vec<u8>>) -> Self {
        let names = (0..cards.len()).map(|i| format!("X{i}")).collect();
        Dataset::new(names, cards, cols)
    }

    /// Number of variables.
    #[inline]
    pub fn n_vars(&self) -> usize {
        self.cols.len()
    }

    /// Number of instances.
    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Cardinality of variable `i`.
    #[inline]
    pub fn card(&self, i: usize) -> u32 {
        self.cards[i]
    }

    /// All cardinalities.
    #[inline]
    pub fn cards(&self) -> &[u32] {
        &self.cards
    }

    /// Column `i`'s states.
    #[inline]
    pub fn col(&self, i: usize) -> &[u8] {
        &self.cols[i]
    }

    /// Variable names.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Name of variable `i`.
    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    /// Index of a variable by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Maximum cardinality across variables.
    pub fn max_card(&self) -> u32 {
        self.cards.iter().copied().max().unwrap_or(0)
    }

    /// Row-restricted copy (used by the federated example's horizontal
    /// shards).
    pub fn select_rows(&self, rows: &[usize]) -> Dataset {
        let cols = self
            .cols
            .iter()
            .map(|c| rows.iter().map(|&r| c[r]).collect())
            .collect();
        Dataset { names: self.names.clone(), cards: self.cards.clone(), cols, n_rows: rows.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let d = Dataset::unnamed(vec![2, 3], vec![vec![0, 1, 0], vec![2, 1, 0]]);
        assert_eq!(d.n_vars(), 2);
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.card(1), 3);
        assert_eq!(d.col(0), &[0, 1, 0]);
        assert_eq!(d.name(1), "X1");
        assert_eq!(d.index_of("X0"), Some(0));
        assert_eq!(d.max_card(), 3);
    }

    #[test]
    fn select_rows_subsets() {
        let d = Dataset::unnamed(vec![2, 2], vec![vec![0, 1, 1, 0], vec![1, 1, 0, 0]]);
        let s = d.select_rows(&[0, 3]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.col(0), &[0, 0]);
        assert_eq!(s.col(1), &[1, 0]);
    }

    #[test]
    #[should_panic]
    fn ragged_columns_rejected() {
        Dataset::unnamed(vec![2, 2], vec![vec![0, 1], vec![0]]);
    }
}
