//! Bit-packed columnar view of a [`Dataset`] for the word-parallel
//! counting core (`score::counts`).
//!
//! Two representations per column, both built once per scorer:
//!
//! * **packed codes** — every cell stored in 1/2/4/8 bits chosen from
//!   the column's cardinality, so a `u64` word holds 64/32/16/8 cells.
//!   The multi-parent counting loops decode through [`PackedCol::code`]
//!   (two shifts + a mask) instead of a byte load per cell, and a
//!   row-block of any family's columns fits in a fraction of the cache
//!   footprint of the raw `u8` columns;
//! * **state bit-planes** — for cardinalities ≤ [`PLANE_MAX_CARD`],
//!   one bitmask per state (`planes[s]` bit `t` set iff row `t` has
//!   state `s`). Zero- and one-parent family counts — the dominant
//!   call shape in GES pairwise deltas — then reduce to
//!   `popcount(plane_a & plane_b)` over whole words: 64 rows per
//!   instruction, no per-row scatter-increment at all.
//!
//! Bits past `n_rows` in every plane word are zero, so popcounts need
//! no tail masking.

use crate::data::Dataset;

/// Largest cardinality that gets per-state bit-planes. Beyond this the
/// plane set costs more memory than the popcount path saves time, and
/// the scalar packed-decode path takes over.
pub const PLANE_MAX_CARD: u32 = 8;

/// One bit-packed column: packed codes plus optional state planes.
pub struct PackedCol {
    card: u32,
    /// Bits per cell: 1, 2, 4 or 8.
    bits: u32,
    /// `(1 << bits) - 1`.
    code_mask: u64,
    /// `log2(cells per word)` — row `t` lives in word `t >> idx_shift`.
    idx_shift: u32,
    /// `cells per word - 1` — cell index within the word.
    pos_mask: usize,
    /// `log2(bits)` — bit offset is `(t & pos_mask) << bits_shift`.
    bits_shift: u32,
    codes: Vec<u64>,
    planes: Option<Vec<Vec<u64>>>,
}

impl PackedCol {
    fn pack(col: &[u8], card: u32) -> PackedCol {
        let bits: u32 = match card {
            0..=2 => 1,
            3..=4 => 2,
            5..=16 => 4,
            _ => 8,
        };
        let bits_shift = bits.trailing_zeros();
        let idx_shift = 6 - bits_shift;
        let pos_mask = (64usize >> bits_shift) - 1;
        let m = col.len();
        let mut codes = vec![0u64; m.div_ceil(1 << idx_shift)];
        for (t, &s) in col.iter().enumerate() {
            let off = (t & pos_mask) << bits_shift;
            codes[t >> idx_shift] |= (s as u64) << off;
        }
        let planes = (card <= PLANE_MAX_CARD).then(|| {
            let words = m.div_ceil(64);
            let mut planes = vec![vec![0u64; words]; card as usize];
            for (t, &s) in col.iter().enumerate() {
                planes[s as usize][t >> 6] |= 1u64 << (t & 63);
            }
            planes
        });
        PackedCol { card, bits: 1 << bits_shift, code_mask: (1u64 << bits) - 1, idx_shift, pos_mask, bits_shift, codes, planes }
    }

    /// Cardinality of the variable.
    #[inline]
    pub fn card(&self) -> u32 {
        self.card
    }

    /// Bits per cell (1, 2, 4 or 8).
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Decode the state of row `t`.
    #[inline]
    pub fn code(&self, t: usize) -> usize {
        let w = self.codes[t >> self.idx_shift];
        let off = (t & self.pos_mask) << self.bits_shift;
        ((w >> off) & self.code_mask) as usize
    }

    /// Per-state bit-planes (`None` when `card > PLANE_MAX_CARD`).
    /// `planes()[s]` has bit `t % 64` of word `t / 64` set iff row `t`
    /// is in state `s`; bits past the last row are zero.
    #[inline]
    pub fn planes(&self) -> Option<&[Vec<u64>]> {
        self.planes.as_deref()
    }
}

/// Bit-packed view of a whole dataset.
pub struct PackedData {
    cols: Vec<PackedCol>,
    n_rows: usize,
    words: usize,
}

impl PackedData {
    /// Pack every column of `data`.
    pub fn pack(data: &Dataset) -> PackedData {
        let cols = (0..data.n_vars()).map(|i| PackedCol::pack(data.col(i), data.card(i))).collect();
        PackedData { cols, n_rows: data.n_rows(), words: data.n_rows().div_ceil(64) }
    }

    /// Packed column `i`.
    #[inline]
    pub fn col(&self, i: usize) -> &PackedCol {
        &self.cols[i]
    }

    /// Number of rows (shared by every column).
    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Plane length in `u64` words (`n_rows / 64`, rounded up).
    #[inline]
    pub fn words(&self) -> usize {
        self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_data(cards: &[u32], rows: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let cols = cards
            .iter()
            .map(|&c| (0..rows).map(|_| rng.gen_range(c as usize) as u8).collect())
            .collect();
        Dataset::unnamed(cards.to_vec(), cols)
    }

    #[test]
    fn codes_roundtrip_all_widths() {
        // One column per packing width, rows not a multiple of 64.
        let cards = [2u32, 3, 4, 5, 16, 17, 21];
        for rows in [0usize, 1, 63, 64, 65, 250] {
            let d = random_data(&cards, rows, rows as u64 + 1);
            let p = PackedData::pack(&d);
            assert_eq!(p.n_rows(), rows);
            for (i, &card) in cards.iter().enumerate() {
                let pc = p.col(i);
                assert_eq!(pc.card(), card);
                for t in 0..rows {
                    assert_eq!(
                        pc.code(t),
                        d.col(i)[t] as usize,
                        "col {i} (card {card}, {} bits) row {t}",
                        pc.bits()
                    );
                }
            }
        }
    }

    #[test]
    fn planes_partition_rows_exactly() {
        let cards = [2u32, 4, 8, 9];
        let rows = 173;
        let d = random_data(&cards, rows, 99);
        let p = PackedData::pack(&d);
        for (i, &card) in cards.iter().enumerate() {
            let pc = p.col(i);
            if card > PLANE_MAX_CARD {
                assert!(pc.planes().is_none(), "col {i} should have no planes");
                continue;
            }
            let planes = pc.planes().expect("planes for low-card column");
            assert_eq!(planes.len(), card as usize);
            // Per-state popcounts match the raw column's histogram.
            for (s, plane) in planes.iter().enumerate() {
                let pop: u32 = plane.iter().map(|w| w.count_ones()).sum();
                let raw = d.col(i).iter().filter(|&&v| v as usize == s).count();
                assert_eq!(pop as usize, raw, "col {i} state {s}");
            }
            // States are disjoint and cover every row; no bits past m.
            let mut all = vec![0u64; p.words()];
            for plane in planes {
                for (a, w) in all.iter_mut().zip(plane) {
                    assert_eq!(*a & w, 0, "overlapping planes in col {i}");
                    *a |= w;
                }
            }
            let total: u32 = all.iter().map(|w| w.count_ones()).sum();
            assert_eq!(total as usize, rows, "col {i} planes must cover all rows");
        }
    }
}
