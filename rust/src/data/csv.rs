//! CSV interchange for datasets: header row of variable names, integer
//! state values. Cardinalities are inferred as `max state + 1` unless a
//! `#cards:` comment line supplies them (the sampler always writes it,
//! so round-trips are exact even if a rare state never occurs in the
//! sample).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;

/// Write `data` as CSV (with a `#cards:` header comment).
pub fn write_csv(data: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    let cards: Vec<String> = data.cards().iter().map(|c| c.to_string()).collect();
    writeln!(f, "#cards: {}", cards.join(","))?;
    writeln!(f, "{}", data.names().join(","))?;
    for r in 0..data.n_rows() {
        let row: Vec<String> = (0..data.n_vars()).map(|v| data.col(v)[r].to_string()).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a dataset written by [`write_csv`] (or any integer CSV).
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut lines = BufReader::new(f).lines();

    let mut first = lines.next().context("empty csv")??;
    let mut cards: Option<Vec<u32>> = None;
    if let Some(rest) = first.strip_prefix("#cards:") {
        cards = Some(
            rest.trim()
                .split(',')
                .map(|s| s.trim().parse::<u32>().context("bad #cards entry"))
                .collect::<Result<_>>()?,
        );
        first = lines.next().context("csv missing header")??;
    }
    let names: Vec<String> = first.split(',').map(|s| s.trim().to_string()).collect();
    let n = names.len();

    let mut cols: Vec<Vec<u8>> = vec![Vec::new(); n];
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != n {
            bail!("row {} has {} fields, expected {}", lineno + 2, fields.len(), n);
        }
        for (v, s) in fields.iter().enumerate() {
            let val: u32 = s.trim().parse().with_context(|| format!("row {lineno}, col {v}"))?;
            if val > u8::MAX as u32 {
                bail!("state {val} exceeds u8 range (col {v})");
            }
            cols[v].push(val as u8);
        }
    }

    let cards = cards.unwrap_or_else(|| {
        cols.iter()
            .map(|c| c.iter().copied().max().map(|m| m as u32 + 1).unwrap_or(1))
            .collect()
    });
    Ok(Dataset::new(names, cards, cols))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let d = Dataset::unnamed(vec![3, 2], vec![vec![0, 2, 1], vec![1, 0, 1]]);
        let tmp = std::env::temp_dir().join("cges_csv_roundtrip.csv");
        write_csv(&d, &tmp).unwrap();
        let r = read_csv(&tmp).unwrap();
        assert_eq!(r.cards(), d.cards());
        assert_eq!(r.col(0), d.col(0));
        assert_eq!(r.col(1), d.col(1));
        assert_eq!(r.names(), d.names());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn infers_cards_without_header() {
        let tmp = std::env::temp_dir().join("cges_csv_nocards.csv");
        std::fs::write(&tmp, "a,b\n0,1\n2,0\n").unwrap();
        let r = read_csv(&tmp).unwrap();
        assert_eq!(r.cards(), &[3, 2]);
        std::fs::remove_file(&tmp).ok();
    }
}
