//! Dataset substrate: column-major discrete data + CSV interchange.

pub mod csv;
pub mod dataset;

pub use csv::{read_csv, write_csv};
pub use dataset::Dataset;
