//! Dataset substrate: column-major discrete data + CSV interchange.

pub mod csv;
pub mod dataset;
pub mod packed;

pub use csv::{read_csv, write_csv};
pub use dataset::Dataset;
pub use packed::{PackedCol, PackedData, PLANE_MAX_CARD};
