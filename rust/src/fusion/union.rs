//! Fusion by edge union of σ-consistent transforms.
//!
//! Once every input DAG has been made σ-consistent, all edges point
//! forward in σ, so their union is again a DAG — and it is an I-map of
//! every input (contains every input's independence constraints'
//! edges). This is `Fusion.edgeUnion` in the paper's Algorithm 1.

use crate::fusion::gho::gho_order;
use crate::fusion::imap::sigma_consistent_imap;
use crate::graph::Dag;

/// Fuse with an explicitly supplied order.
pub fn fuse_with_order(dags: &[&Dag], sigma: &[usize]) -> Dag {
    assert!(!dags.is_empty());
    let n = dags[0].n();
    let mut out = Dag::new(n);
    for &g in dags {
        let t = sigma_consistent_imap(g, sigma);
        for (u, v) in t.edges() {
            out.add_edge(u, v);
        }
    }
    debug_assert!(out.is_acyclic());
    out
}

/// Full fusion: GHO order + transform + union. Returns the fused DAG
/// and the order used (for telemetry).
pub fn fuse(dags: &[&Dag]) -> (Dag, Vec<usize>) {
    let sigma = gho_order(dags);
    let fused = fuse_with_order(dags, &sigma);
    (fused, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_of_identical_is_identity() {
        let g = Dag::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let (f, _sigma) = fuse(&[&g, &g]);
        let mut e1 = g.edges();
        let mut e2 = f.edges();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
    }

    #[test]
    fn union_contains_both_inputs_modulo_sigma() {
        // Disjoint claims: G1 has 0 -> 1, G2 has 2 -> 3. Fusion must
        // keep both adjacencies.
        let g1 = Dag::from_edges(4, &[(0, 1)]);
        let g2 = Dag::from_edges(4, &[(2, 3)]);
        let (f, _) = fuse(&[&g1, &g2]);
        assert!(f.adjacent(0, 1));
        assert!(f.adjacent(2, 3));
        assert!(f.is_acyclic());
    }

    #[test]
    fn conflicting_directions_still_acyclic() {
        let g1 = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let g2 = Dag::from_edges(3, &[(2, 1), (1, 0)]);
        let (f, sigma) = fuse(&[&g1, &g2]);
        assert!(f.is_acyclic());
        // Both skeleton adjacencies survive.
        assert!(f.adjacent(0, 1) && f.adjacent(1, 2));
        assert_eq!(sigma.len(), 3);
    }

    #[test]
    fn fusion_is_edge_superset_of_each_transform() {
        let g1 = Dag::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let g2 = Dag::from_edges(5, &[(0, 2), (2, 4), (1, 3)]);
        let (f, sigma) = fuse(&[&g1, &g2]);
        for g in [&g1, &g2] {
            let t = sigma_consistent_imap(g, &sigma);
            for (u, v) in t.edges() {
                assert!(f.has_edge(u, v), "missing {u}->{v}");
            }
        }
    }
}
