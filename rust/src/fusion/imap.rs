//! σ-consistent independence-map transformation.
//!
//! Given a DAG G and a total order σ, produce a DAG Ĝ whose edges all
//! point forward in σ and that is an I-map of G (it represents no
//! independence G rejects). The construction processes nodes from the
//! back of σ, making each a sink among the still-unprocessed nodes via
//! I-map-preserving arc reversals (`fusion::gho::make_sink`), exactly
//! the transformation whose cost GHO minimizes.

use crate::fusion::gho::make_sink;
use crate::graph::Dag;

/// Transform `g` into a σ-consistent I-map.
pub fn sigma_consistent_imap(g: &Dag, sigma: &[usize]) -> Dag {
    let n = g.n();
    assert_eq!(sigma.len(), n, "σ must be a permutation of the nodes");
    let mut work = g.clone();
    let mut removed = vec![false; n];
    // Back to front: σ's last element becomes a global sink first.
    for &v in sigma.iter().rev() {
        make_sink(&mut work, v, &removed);
        removed[v] = true;
    }
    debug_assert!(work.is_acyclic());
    // All edges now point forward in σ.
    debug_assert!({
        let mut pos = vec![0usize; n];
        for (i, &v) in sigma.iter().enumerate() {
            pos[v] = i;
        }
        work.edges().iter().all(|&(u, v)| pos[u] < pos[v])
    });
    work
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{d_separated, dsep::d_connected};
    use crate::rng::Rng;
    use crate::util::BitSet;

    fn positions(sigma: &[usize]) -> Vec<usize> {
        let mut p = vec![0; sigma.len()];
        for (i, &v) in sigma.iter().enumerate() {
            p[v] = i;
        }
        p
    }

    #[test]
    fn consistent_order_is_identity() {
        let g = Dag::from_edges(4, &[(0, 1), (1, 2), (0, 3)]);
        let t = sigma_consistent_imap(&g, &[0, 1, 2, 3]);
        let mut e1 = g.edges();
        let mut e2 = t.edges();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
    }

    #[test]
    fn reversed_order_keeps_imap_property() {
        // Chain 0 -> 1 -> 2 under σ = (2, 1, 0): result must encode no
        // independence the chain lacks. The chain has exactly
        // 0 ⫫ 2 | 1; the transform may lose it but must not invent
        // others.
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let t = sigma_consistent_imap(&g, &[2, 1, 0]);
        assert!(t.is_acyclic());
        let pos = positions(&[2, 1, 0]);
        for (u, v) in t.edges() {
            assert!(pos[u] < pos[v]);
        }
        // I-map check: every d-separation in t must hold in g.
        let n = 3;
        for x in 0..n {
            for y in (x + 1)..n {
                for z_bits in 0..(1u8 << n) {
                    let z = BitSet::from_iter(
                        n,
                        (0..n).filter(|&i| i != x && i != y && (z_bits >> i) & 1 == 1),
                    );
                    if d_separated(&t, x, y, &z) {
                        assert!(
                            d_separated(&g, x, y, &z),
                            "t claims {x} ⫫ {y} | {z:?} but g rejects it"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn random_dags_imap_under_random_orders() {
        // Property: for random small DAGs and random σ, the transform
        // is a σ-consistent I-map (checked exhaustively by d-sep).
        let mut rng = Rng::new(99);
        for trial in 0..25 {
            let n = 5;
            let cfg = crate::bn::NetGenConfig {
                nodes: n,
                edges: 6,
                max_parents: 3,
                locality: 0,
                ..Default::default()
            };
            let g = crate::bn::netgen::random_dag(&cfg, trial);
            let mut sigma: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut sigma);
            let t = sigma_consistent_imap(&g, &sigma);
            let pos = positions(&sigma);
            for (u, v) in t.edges() {
                assert!(pos[u] < pos[v], "trial {trial}: edge {u}->{v} violates σ");
            }
            for x in 0..n {
                for y in (x + 1)..n {
                    for z_bits in 0..(1u16 << n) {
                        let z = BitSet::from_iter(
                            n,
                            (0..n).filter(|&i| i != x && i != y && (z_bits >> i) & 1 == 1),
                        );
                        if d_separated(&t, x, y, &z) && d_connected(&g, x, y, &z) {
                            panic!("trial {trial}: invented independence {x} ⫫ {y} | {z:?}");
                        }
                    }
                }
            }
        }
    }
}
