//! Bayesian-network structural fusion (Puerta, Aledo, Gámez, Laborda —
//! Information Fusion 66, 2021), the core component of the ring's
//! message handling.
//!
//! Fusing DAGs G_1..G_k:
//! 1. find a common ancestral order σ with the **G**reedy **H**euristic
//!    **O**rdering (GHO): repeatedly pick the node that is cheapest to
//!    turn into a sink across all input DAGs ([`gho`]);
//! 2. transform each G_i into a σ-consistent (independence-preserving)
//!    DAG via covered-edge-style reversals ([`imap`]);
//! 3. take the edge union — σ-consistency of all inputs makes the
//!    union acyclic ([`union`]).
//!
//! The ring uses the 2-argument form (own model + predecessor's model),
//! which the paper points out keeps fused structures sparse and
//! mitigates overfitting.

pub mod gho;
pub mod imap;
pub mod union;

pub use gho::gho_order;
pub use imap::sigma_consistent_imap;
pub use union::{fuse, fuse_with_order};
