//! Greedy Heuristic Ordering (GHO) for fusion.
//!
//! The fusion result depends strongly on the common variable order σ;
//! GHO (Puerta et al. 2021) builds σ from the back by repeatedly
//! selecting the node with the minimum total *sink cost* — the number
//! of edges the reversal procedure would add across all input DAGs to
//! make that node a sink — then actually sinking it and recursing on
//! the remaining nodes.

use crate::graph::Dag;

/// Cost of making `v` a sink in `g`, restricted to non-removed nodes:
/// `(edges_added, reversals)`. This simulates the same reversal order
/// [`make_sink`] uses, so the greedy choice is exact with respect to
/// the transformation actually applied.
pub fn sink_cost(g: &Dag, v: usize, removed: &[bool]) -> (usize, usize) {
    let mut sim = g.clone();
    make_sink(&mut sim, v, removed)
}

/// Turn `v` into a sink in-place by reversing its outgoing edges
/// (to non-removed children), augmenting parents to keep the graph an
/// I-map of the original (Shachter-style arc reversal). Returns
/// `(edges_added, reversals)`.
pub fn make_sink(g: &mut Dag, v: usize, removed: &[bool]) -> (usize, usize) {
    let mut added = 0usize;
    let mut reversals = 0usize;
    loop {
        // Children of v still in play.
        let children: Vec<usize> = g.children(v).iter().filter(|&c| !removed[c]).collect();
        if children.is_empty() {
            return (added, reversals);
        }
        // Reverse v -> c where no *other* child of v reaches c: a cycle
        // after reversal needs a path v -> o ⇝ c, so choosing c minimal
        // in the children-reachability order makes reversal safe.
        let c = *children
            .iter()
            .find(|&&c| children.iter().all(|&o| o == c || !g.has_directed_path(o, c)))
            .expect("a DAG always has a reachability-minimal child");
        reversals += 1;
        // Arc reversal v -> c: both endpoints inherit the other's
        // parents (minus themselves).
        let pa_v: Vec<usize> = g.parents(v).iter().collect();
        let pa_c: Vec<usize> = g.parents(c).iter().filter(|&p| p != v).collect();
        for &p in &pa_v {
            if p != c && !g.has_edge(p, c) {
                g.add_edge(p, c);
                added += 1;
            }
        }
        for &p in &pa_c {
            if p != v && !g.has_edge(p, v) {
                g.add_edge(p, v);
                added += 1;
            }
        }
        g.remove_edge(v, c);
        g.add_edge(c, v);
        debug_assert!(g.is_acyclic());
    }
}

/// Cheap sink-cost estimate used inside the GHO selection loop:
/// the parent-copy cost of the *first* reversal of each outgoing edge,
/// ignoring the cascade effects later reversals add. One bitset pass
/// per child — no graph clone, no simulation. (The §Perf pass replaced
/// the exact simulated cost here: fusion dominated ring rounds at
/// n ≥ 200, see EXPERIMENTS.md. Selection quality is heuristic either
/// way — the GHO paper itself scores candidate orders heuristically —
/// and the applied transformation stays exact.)
pub fn sink_cost_estimate(g: &Dag, v: usize, removed: &[bool]) -> (usize, usize) {
    let mut added = 0usize;
    let mut reversals = 0usize;
    let pa_v = g.parents(v);
    for c in g.children(v).iter() {
        if removed[c] {
            continue;
        }
        reversals += 1;
        let pa_c = g.parents(c);
        // p -> c for p in Pa(v) \ Pa(c) \ {c}
        let mut need_c = pa_v.clone();
        need_c.difference_with(pa_c);
        need_c.remove(c);
        // p -> v for p in Pa(c) \ Pa(v) \ {v}
        let mut need_v = pa_c.clone();
        need_v.difference_with(pa_v);
        need_v.remove(v);
        added += need_c.count() + need_v.count();
    }
    (added, reversals)
}

/// GHO: a common order σ (first element = first in the order) that
/// greedily minimizes total reversal cost across `dags`.
pub fn gho_order(dags: &[&Dag]) -> Vec<usize> {
    assert!(!dags.is_empty());
    let n = dags[0].n();
    let mut work: Vec<Dag> = dags.iter().map(|&g| g.clone()).collect();
    let mut removed = vec![false; n];
    let mut sigma_rev = Vec::with_capacity(n);

    for _ in 0..n {
        // Node with minimum total (edges added, reversals) — preferring
        // true sinks among zero-cost candidates keeps fusion of
        // identical/compatible DAGs an identity; ties broken by index
        // for determinism.
        let mut best: Option<((usize, usize), usize)> = None;
        for v in 0..n {
            if removed[v] {
                continue;
            }
            let mut cost = (0usize, 0usize);
            for g in &work {
                let (a, r) = sink_cost_estimate(g, v, &removed);
                cost.0 += a;
                cost.1 += r;
            }
            if best.map(|(bc, _)| cost < bc).unwrap_or(true) {
                best = Some((cost, v));
            }
            if cost == (0, 0) {
                break; // a true common sink; cannot do better
            }
        }
        let (_, v) = best.expect("nodes remain");
        for g in &mut work {
            make_sink(g, v, &removed);
        }
        removed[v] = true;
        sigma_rev.push(v);
    }
    sigma_rev.reverse();
    sigma_rev
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_cost_zero_for_sinks() {
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let removed = vec![false; 3];
        assert_eq!(sink_cost(&g, 2, &removed), (0, 0));
        // Sinking the middle node reverses 1->2 and must add 0->2.
        let (added, revs) = sink_cost(&g, 1, &removed);
        assert_eq!((added, revs), (1, 1));
        // Sinking the root reverses its only edge; no parents to copy.
        assert_eq!(sink_cost(&g, 0, &removed), (0, 1));
    }

    #[test]
    fn make_sink_preserves_acyclicity_and_sinkness() {
        let mut g = Dag::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let removed = vec![false; 4];
        make_sink(&mut g, 0, &removed);
        assert!(g.is_acyclic());
        assert_eq!(g.children(0).count(), 0);
    }

    #[test]
    fn gho_respects_topology_of_single_dag() {
        // For a single DAG, GHO should return a topological order
        // (sinks have cost 0 and are picked from the back).
        let g = Dag::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let sigma = gho_order(&[&g]);
        let pos: Vec<usize> = {
            let mut p = vec![0; 5];
            for (i, &v) in sigma.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for (u, v) in g.edges() {
            assert!(pos[u] < pos[v], "σ must be consistent with {u}->{v}");
        }
    }

    #[test]
    fn gho_handles_disagreeing_dags() {
        // G1: 0 -> 1, G2: 1 -> 0 — any order works, cost reflects one
        // reversal somewhere; just verify a valid permutation comes out.
        let g1 = Dag::from_edges(2, &[(0, 1)]);
        let g2 = Dag::from_edges(2, &[(1, 0)]);
        let mut sigma = gho_order(&[&g1, &g2]);
        sigma.sort_unstable();
        assert_eq!(sigma, vec![0, 1]);
    }
}
