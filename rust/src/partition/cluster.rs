//! Score-guided hierarchical clustering of variables (stage 1 of cGES).
//!
//! Agglomerative clustering over the pairwise BDeu similarity
//! `s(X_i, X_j)` (Eq. 4, computed by the AOT artifact or the Rust
//! fallback), with inter-cluster similarity the size-normalized sum of
//! Eq. 5 — i.e. the average pairwise similarity (the paper labels the
//! scheme complete-link; the formula it gives is average-link, which we
//! follow). Lance–Williams updates keep merges O(n) each; a per-row
//! nearest-neighbor cache keeps the whole run O(n²) amortized.

/// Cluster labels (0..k) for each variable.
pub fn cluster_variables(s: &[Vec<f64>], k: usize) -> Vec<usize> {
    let n = s.len();
    assert!(k >= 1 && k <= n.max(1));
    if n == 0 {
        return Vec::new();
    }

    // Symmetrized working copy (BDeu pair scores are symmetric up to
    // float noise; make it exact).
    let mut sim = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for j in 0..n {
            sim[i][j] = 0.5 * (s[i][j] + s[j][i]);
        }
    }

    let mut active: Vec<bool> = vec![true; n];
    let mut size: Vec<usize> = vec![1; n];
    let mut label: Vec<usize> = (0..n).collect(); // representative per var
    let mut n_active = n;

    // Row-best cache: best[i] = (sim, j) over active j != i.
    let mut best: Vec<Option<(f64, usize)>> = (0..n)
        .map(|i| {
            (0..n)
                .filter(|&j| j != i)
                .map(|j| (sim[i][j], j))
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
        })
        .collect();

    while n_active > k {
        // Global best merge from the row caches (refresh stale rows).
        let mut pick: Option<(f64, usize, usize)> = None;
        for i in 0..n {
            if !active[i] {
                continue;
            }
            // Refresh if cached partner died.
            if let Some((_, j)) = best[i] {
                if !active[j] {
                    best[i] = (0..n)
                        .filter(|&j2| j2 != i && active[j2])
                        .map(|j2| (sim[i][j2], j2))
                        .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                }
            }
            if let Some((v, j)) = best[i] {
                if pick.map(|(pv, _, _)| v > pv).unwrap_or(true) {
                    pick = Some((v, i, j));
                }
            }
        }
        let (_, a, b) = pick.expect("at least two active clusters");
        debug_assert!(active[a] && active[b] && a != b);

        // Merge b into a (average-link Lance–Williams).
        let (sa, sb) = (size[a] as f64, size[b] as f64);
        for j in 0..n {
            if j != a && j != b && active[j] {
                let v = (sa * sim[a][j] + sb * sim[b][j]) / (sa + sb);
                sim[a][j] = v;
                sim[j][a] = v;
            }
        }
        active[b] = false;
        size[a] += size[b];
        n_active -= 1;
        for l in label.iter_mut() {
            if *l == b {
                *l = a;
            }
        }
        // Rows pointing at a or b are stale; so is a's own row.
        best[a] = (0..n)
            .filter(|&j| j != a && active[j])
            .map(|j| (sim[a][j], j))
            .max_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        for i in 0..n {
            if active[i] && i != a {
                if let Some((_, j)) = best[i] {
                    if j == a || j == b {
                        best[i] = (0..n)
                            .filter(|&j2| j2 != i && active[j2])
                            .map(|j2| (sim[i][j2], j2))
                            .max_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
                    }
                }
            }
        }
    }

    // Compact representative ids to 0..k.
    let mut remap = std::collections::HashMap::new();
    let mut out = vec![0usize; n];
    for i in 0..n {
        let next_id = remap.len();
        let id = *remap.entry(label[i]).or_insert(next_id);
        out[i] = id;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Block-diagonal similarity: two obvious groups.
    fn blocky(n: usize, split: usize) -> Vec<Vec<f64>> {
        let mut s = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let same = (i < split) == (j < split);
                s[i][j] = if same { 10.0 } else { -5.0 };
            }
        }
        s
    }

    #[test]
    fn recovers_two_blocks() {
        let s = blocky(10, 4);
        let labels = cluster_variables(&s, 2);
        let first = labels[0];
        assert!(labels[..4].iter().all(|&l| l == first));
        let second = labels[4];
        assert_ne!(first, second);
        assert!(labels[4..].iter().all(|&l| l == second));
    }

    #[test]
    fn k_equals_n_is_singletons() {
        let s = blocky(5, 2);
        let labels = cluster_variables(&s, 5);
        let mut sorted = labels.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
    }

    #[test]
    fn k_one_merges_everything() {
        let s = blocky(6, 3);
        let labels = cluster_variables(&s, 1);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn label_count_matches_k() {
        let s = blocky(12, 5);
        for k in 1..=6 {
            let labels = cluster_variables(&s, k);
            let mut ids = labels.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), k, "k={k}");
        }
    }
}
