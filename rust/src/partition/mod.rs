//! Stage 1 of cGES: score-guided partitioning of the candidate-edge
//! set into k balanced subsets (clustering + assignment).

pub mod assign;
pub mod cluster;

pub use assign::{assign_edges, partition_stats, PartitionStats};
pub use cluster::cluster_variables;

use crate::learn::EdgeMask;

/// One-call partition: similarity matrix -> k edge masks.
pub fn partition_edges(s: &[Vec<f64>], k: usize) -> Vec<EdgeMask> {
    let labels = cluster_variables(s, k);
    assign_edges(&labels, k)
}
