//! Edge-subset assignment (stage 1, second half).
//!
//! With k variable clusters in hand: all pairs inside cluster i go to
//! subset E_i; every cross-cluster pair goes to whichever of its two
//! endpoint subsets currently holds fewer edges (the paper's balancing
//! rule). The result is a disjoint cover of all unordered pairs.

use crate::learn::EdgeMask;

/// Build the k edge masks from per-variable cluster labels.
pub fn assign_edges(labels: &[usize], k: usize) -> Vec<EdgeMask> {
    let n = labels.len();
    let mut masks: Vec<EdgeMask> = (0..k).map(|_| EdgeMask::new(n)).collect();

    // Intra-cluster pairs first.
    for i in 0..n {
        for j in (i + 1)..n {
            if labels[i] == labels[j] {
                masks[labels[i]].allow(i, j);
            }
        }
    }
    // Cross pairs balanced to the lighter endpoint subset.
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = (labels[i], labels[j]);
            if a != b {
                let target = if masks[a].len() <= masks[b].len() { a } else { b };
                masks[target].allow(i, j);
            }
        }
    }
    masks
}

/// Partition diagnostics.
pub struct PartitionStats {
    pub sizes: Vec<usize>,
    pub total: usize,
    pub expected: usize,
}

/// Validate a partition covers all pairs disjointly; returns stats.
pub fn partition_stats(masks: &[EdgeMask], n: usize) -> PartitionStats {
    let sizes: Vec<usize> = masks.iter().map(|m| m.len()).collect();
    PartitionStats { total: sizes.iter().sum(), sizes, expected: n * (n - 1) / 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_pairs_disjointly() {
        let labels = vec![0, 0, 1, 1, 2, 2, 0];
        let n = labels.len();
        let masks = assign_edges(&labels, 3);
        let stats = partition_stats(&masks, n);
        assert_eq!(stats.total, stats.expected, "cover must be exact");
        // Disjoint: each pair in exactly one mask.
        for i in 0..n {
            for j in (i + 1)..n {
                let owners = masks.iter().filter(|m| m.allowed(i, j)).count();
                assert_eq!(owners, 1, "pair ({i},{j}) owned by {owners} masks");
            }
        }
    }

    #[test]
    fn intra_cluster_pairs_stay_home() {
        let labels = vec![0, 0, 0, 1, 1];
        let masks = assign_edges(&labels, 2);
        assert!(masks[0].allowed(0, 1) && masks[0].allowed(1, 2) && masks[0].allowed(0, 2));
        assert!(masks[1].allowed(3, 4));
    }

    #[test]
    fn balancing_keeps_sizes_close() {
        // One big cluster + one small: cross edges should flow to the
        // smaller subset.
        let mut labels = vec![0usize; 20];
        labels[18] = 1;
        labels[19] = 1;
        let masks = assign_edges(&labels, 2);
        let s0 = masks[0].len() as f64;
        let s1 = masks[1].len() as f64;
        // Without balancing subset 1 would have 1 + 36 pairs at most;
        // with balancing it should absorb nearly all cross pairs.
        assert!(s1 > 30.0, "s1={s1}");
        let total = s0 + s1;
        assert_eq!(total as usize, 20 * 19 / 2);
    }
}
