//! # cGES — Ring-Based Distributed Learning of High-Dimensional Bayesian Networks
//!
//! Rust + JAX/Pallas reproduction of *"A Ring-Based Distributed
//! Algorithm for Learning High-Dimensional Bayesian Networks"*
//! (Laborda, Torrijos, Puerta, Gámez — LNCS 14294).
//!
//! Three layers:
//! * **L3 (this crate)** — the ring coordinator, GES/fGES learners,
//!   BN fusion, edge partitioning, scoring, metrics and CLI;
//! * **L2 (python/compile/model.py)** — the pairwise-BDeu similarity
//!   graph, AOT-lowered to HLO text at build time;
//! * **L1 (python/compile/kernels/)** — the blocked Pallas kernel the
//!   L2 graph calls.
//!
//! The learning path is pure Rust; XLA artifacts are loaded through
//! [`runtime`] and executed via PJRT. See `DESIGN.md` for the full
//! system inventory.

pub mod bn;
pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod fusion;
pub mod data;
pub mod graph;
pub mod infer;
pub mod learn;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod score;
pub mod util;

/// Convenience re-exports for examples and downstream users, curated
/// around the [`crate::model::Bundle`] pipeline: learn
/// ([`crate::coordinator::cges`]) → bundle → warm serve
/// ([`crate::engine::CompiledModel::from_bundle`],
/// [`crate::engine::Server`]). The PR 2 single-threaded shims
/// (`infer::QueryServer`, `infer::JoinTree`) stay available under
/// [`crate::infer`] but are no longer part of the prelude — new code
/// should speak bundles and the compiled engine.
pub mod prelude {
    pub use crate::bn::{fit, forward_sample, load_domain, DiscreteBn, Domain, NetGenConfig};
    pub use crate::coordinator::{cges, run_ring, RingConfig, RingMode, RingResult};
    pub use crate::data::Dataset;
    pub use crate::engine::{
        CompiledModel, FleetConfig, FleetServer, ModelRegistry, Scratch, ServeConfig, Server,
        SharedEngine,
    };
    pub use crate::graph::{Dag, Pdag};
    pub use crate::infer::{
        likelihood_weighting, ve_marginal, Engine, EngineConfig, Method, Posterior,
    };
    pub use crate::model::{
        bundle_fingerprint, fingerprint_hex, read_bundle, write_bundle, Bundle, BundleMeta,
        CalibratedPotentials,
    };
    pub use crate::rng::Rng;
    pub use crate::score::BdeuScorer;
}
