//! Structural Moral Hamming Distance (SMHD) — the paper's structural
//! quality metric: the Hamming distance between the moralized graphs
//! of the learned and true networks (de Jongh & Druzdzel 2009).

use crate::graph::{moral_graph, Dag};

/// SMHD between two DAGs over the same variable set.
pub fn smhd(a: &Dag, b: &Dag) -> usize {
    assert_eq!(a.n(), b.n());
    let ma = moral_graph(a);
    let mb = moral_graph(b);
    let mut dist = 0usize;
    for v in 0..a.n() {
        // Symmetric difference of adjacency rows, each edge seen twice.
        let mut diff = ma[v].clone();
        diff.difference_with(&mb[v]);
        dist += diff.count();
        let mut diff2 = mb[v].clone();
        diff2.difference_with(&ma[v]);
        dist += diff2.count();
    }
    dist / 2
}

/// SMHD of a DAG against the empty graph (Table 1's "Empty SMHD").
pub fn smhd_vs_empty(g: &Dag) -> usize {
    smhd(g, &Dag::new(g.n()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_zero() {
        let g = Dag::from_edges(4, &[(0, 1), (1, 2), (0, 3)]);
        assert_eq!(smhd(&g, &g), 0);
    }

    #[test]
    fn symmetric() {
        let a = Dag::from_edges(4, &[(0, 1), (1, 2)]);
        let b = Dag::from_edges(4, &[(0, 1), (2, 3)]);
        assert_eq!(smhd(&a, &b), smhd(&b, &a));
    }

    #[test]
    fn counts_moral_edges() {
        // a: 0 -> 2 <- 1 moralizes to triangle (3 edges);
        // b: empty. SMHD = 3.
        let a = Dag::from_edges(3, &[(0, 2), (1, 2)]);
        let b = Dag::new(3);
        assert_eq!(smhd(&a, &b), 3);
        assert_eq!(smhd_vs_empty(&a), 3);
    }

    #[test]
    fn equivalent_dags_zero_distance() {
        // Markov-equivalent chains share the moral graph.
        let a = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let b = Dag::from_edges(3, &[(1, 0), (1, 2)]);
        assert_eq!(smhd(&a, &b), 0);
    }
}
