//! Evaluation metrics: SMHD (the paper's structural score) and the
//! combined per-run report.

pub mod eval;
pub mod smhd;

pub use eval::{evaluate, EvalReport};
pub use smhd::{smhd, smhd_vs_empty};
