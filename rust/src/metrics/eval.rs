//! Learned-structure evaluation: the three scores of the paper's
//! tables (normalized BDeu, SMHD, CPU time) plus skeleton
//! precision/recall diagnostics.

use crate::graph::Dag;
use crate::metrics::smhd::smhd;
use crate::score::BdeuScorer;

/// Evaluation report for one learned structure.
#[derive(Debug, Clone)]
pub struct EvalReport {
    /// BDeu / n_rows — the normalization the paper's Table 2a uses.
    pub bdeu_normalized: f64,
    /// Raw BDeu.
    pub bdeu: f64,
    /// Structural Moral Hamming Distance to the reference.
    pub smhd: usize,
    /// Learned edge count.
    pub edges: usize,
    /// Skeleton precision/recall/F1 against the reference DAG.
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

/// Evaluate `learned` against ground truth + data.
pub fn evaluate(learned: &Dag, truth: &Dag, scorer: &BdeuScorer) -> EvalReport {
    let bdeu = scorer.score_dag(learned);
    let n_rows = scorer.data().n_rows() as f64;

    let skel_l = learned.skeleton();
    let skel_t = truth.skeleton();
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fn_ = 0usize;
    for v in 0..learned.n() {
        let mut inter = skel_l[v].clone();
        inter.intersect_with(&skel_t[v]);
        tp += inter.count();
        let mut onlyl = skel_l[v].clone();
        onlyl.difference_with(&skel_t[v]);
        fp += onlyl.count();
        let mut onlyt = skel_t[v].clone();
        onlyt.difference_with(&skel_l[v]);
        fn_ += onlyt.count();
    }
    let (tp, fp, fn_) = (tp / 2, fp / 2, fn_ / 2);
    let precision = if tp + fp > 0 { tp as f64 / (tp + fp) as f64 } else { 1.0 };
    let recall = if tp + fn_ > 0 { tp as f64 / (tp + fn_) as f64 } else { 1.0 };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };

    EvalReport {
        bdeu_normalized: bdeu / n_rows,
        bdeu,
        smhd: smhd(learned, truth),
        edges: learned.edge_count(),
        precision,
        recall,
        f1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use std::sync::Arc;

    fn scorer() -> BdeuScorer {
        let d = Dataset::unnamed(
            vec![2, 2, 2],
            vec![vec![0, 1, 0, 1], vec![0, 1, 0, 1], vec![1, 0, 1, 0]],
        );
        BdeuScorer::new(Arc::new(d), 10.0)
    }

    #[test]
    fn perfect_recovery() {
        let truth = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let r = evaluate(&truth, &truth, &scorer());
        assert_eq!(r.smhd, 0);
        assert_eq!((r.precision, r.recall, r.f1), (1.0, 1.0, 1.0));
        assert!((r.bdeu_normalized - r.bdeu / 4.0).abs() < 1e-12);
    }

    #[test]
    fn partial_recovery_counts() {
        let truth = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let learned = Dag::from_edges(3, &[(0, 1), (0, 2)]);
        let r = evaluate(&learned, &truth, &scorer());
        // tp = 1 ({0,1}), fp = 1 ({0,2}), fn = 1 ({1,2})
        assert!((r.precision - 0.5).abs() < 1e-12);
        assert!((r.recall - 0.5).abs() < 1e-12);
        assert!(r.smhd > 0);
    }

    #[test]
    fn empty_learned_graph() {
        let truth = Dag::from_edges(3, &[(0, 1)]);
        let r = evaluate(&Dag::new(3), &truth, &scorer());
        assert_eq!(r.edges, 0);
        assert_eq!(r.recall, 0.0);
        assert_eq!(r.precision, 1.0); // no claims, none wrong
    }
}
