//! Minimal data-parallel helpers on std scoped threads.
//!
//! The offline crate set has no rayon; the access patterns we need are
//! simple (embarrassingly parallel candidate scoring, chunked
//! map-reduce), so plain `std::thread::scope` with static chunking is
//! enough and keeps the dependency surface tiny. Thread count defaults
//! to the available parallelism, overridable per call (the paper uses 8
//! threads throughout).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Default worker count: `available_parallelism`, min 1.
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n` with work-stealing via an atomic cursor, in
/// `threads` workers; results are collected in index order.
///
/// `R` needs no `Default`/`Clone`: results are written exactly once
/// into `MaybeUninit` slots, so non-defaultable (and non-clonable)
/// result types work too.
pub fn par_map_index<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    use std::mem::{ManuallyDrop, MaybeUninit};

    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<MaybeUninit<R>> = (0..n).map(|_| MaybeUninit::uninit()).collect();
    let cursor = AtomicUsize::new(0);
    // Grab disjoint output cells through a raw pointer; every index is
    // written by exactly one worker (the atomic cursor hands out unique
    // indices), so this is race-free.
    struct Cells<R>(*mut MaybeUninit<R>);
    unsafe impl<R> Sync for Cells<R> {}
    impl<R> Cells<R> {
        /// Safety: each index is written by exactly one thread.
        unsafe fn write(&self, i: usize, v: R) {
            unsafe { (*self.0.add(i)).write(v) };
        }
    }
    let cells = Cells(out.as_mut_ptr());
    let cells = &cells; // capture the wrapper, not the raw field
    let f = &f; // shared ref is Send because F: Sync
    let cursor = &cursor;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                unsafe { cells.write(i, r) };
            });
        }
    });
    // The scope joined every worker without panicking, so the cursor
    // passed n and each of the n slots was written exactly once: the
    // buffer is fully initialized. (If a worker panicked, the scope
    // propagates the panic above and we never get here — the
    // initialized slots leak rather than double-drop, which is safe.)
    let mut out = ManuallyDrop::new(out);
    unsafe { Vec::from_raw_parts(out.as_mut_ptr() as *mut R, out.len(), out.capacity()) }
}

/// Run `f(i)` for every `i in 0..n` in parallel (side-effect form).
pub fn par_for_each_index<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        (0..n).for_each(f);
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Chunked map over a slice: splits `items` into `threads` contiguous
/// chunks and maps `f` over each chunk concurrently.
pub fn par_chunk_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&[T]) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return vec![f(items)];
    }
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> =
            items.chunks(chunk).map(|c| s.spawn(|| f(c))).collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_index_matches_serial() {
        let par = par_map_index(1000, 8, |i| i * i);
        let ser: Vec<_> = (0..1000).map(|i| i * i).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn map_index_supports_non_default_results() {
        // A result type with neither Default nor Clone.
        struct Payload {
            idx: usize,
            text: String,
        }
        let out = par_map_index(257, 8, |i| Payload { idx: i, text: format!("item-{i}") });
        assert_eq!(out.len(), 257);
        for (i, p) in out.iter().enumerate() {
            assert_eq!(p.idx, i);
            assert_eq!(p.text, format!("item-{i}"));
        }
    }

    #[test]
    fn map_index_drops_results_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Counted;
        impl Drop for Counted {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        let out = par_map_index(500, 4, |_| Counted);
        assert_eq!(out.len(), 500);
        drop(out);
        assert_eq!(DROPS.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn map_index_single_thread_and_empty() {
        assert_eq!(par_map_index(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(par_map_index(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn chunk_map_covers_all() {
        let items: Vec<u64> = (0..10_000).collect();
        let partials = par_chunk_map(&items, 7, |c| c.iter().sum::<u64>());
        assert_eq!(partials.iter().sum::<u64>(), items.iter().sum::<u64>());
    }

    #[test]
    fn for_each_index_runs_all() {
        use std::sync::atomic::AtomicU64;
        let acc = AtomicU64::new(0);
        par_for_each_index(257, 4, |i| {
            acc.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), (0..257u64).sum());
    }
}
