//! Tiny descriptive statistics for the bench harness and telemetry
//! (mean/std over the 11-dataset repetitions, as in the paper's tables).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0.0 for fewer than 2 points.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Summary of repeated measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    /// Summarize a sample.
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            mean: mean(xs),
            std: std_dev(xs),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            n: xs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13808993529939).abs() < 1e-9);
    }

    #[test]
    fn summary_edges() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        let s = Summary::of(&[1.0, 3.0]);
        assert_eq!((s.min, s.max, s.n), (1.0, 3.0, 2));
    }
}
