//! Wall-clock timing, now provided by [`crate::obs::clock`].
//!
//! The historical `util::Timer` API lives on unchanged as a view over
//! [`crate::obs::clock::Stopwatch`]; this module re-exports both so
//! every pre-obs call site keeps compiling.

pub use crate::obs::clock::{Stopwatch, Timer};
