//! Wall-clock timing helper used by telemetry and the bench harness.

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Reset the start point.
    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a && a >= 0.0);
    }
}
