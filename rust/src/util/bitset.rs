//! Fixed-capacity bitset used for adjacency rows, node sets and clique
//! checks throughout the graph layer. Capacity is the number of
//! variables (≤ a few thousand), so a `Vec<u64>` of ~n/64 words keeps
//! set algebra (union/intersection/subset) in a handful of SIMD-friendly
//! word ops — the workhorse of the GES operator validity tests.

/// Fixed-capacity bitset over `len` bits.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// Empty set with capacity for `len` elements.
    pub fn new(len: usize) -> Self {
        BitSet { words: vec![0; len.div_ceil(64)], len }
    }

    /// Capacity (number of addressable elements).
    #[inline]
    pub fn capacity(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i >> 6] >> (i & 63) & 1 == 1
    }

    /// Number of elements in the set.
    #[inline]
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// `self ∪= other`.
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// `self ∩= other`.
    pub fn intersect_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// `self \= other`.
    pub fn difference_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// Fresh `self ∪ other`.
    pub fn union(&self, other: &BitSet) -> BitSet {
        let mut out = self.clone();
        out.union_with(other);
        out
    }

    /// Fresh `self ∩ other`.
    pub fn intersection(&self, other: &BitSet) -> BitSet {
        let mut out = self.clone();
        out.intersect_with(other);
        out
    }

    /// Fresh `self \ other`.
    pub fn difference(&self, other: &BitSet) -> BitSet {
        let mut out = self.clone();
        out.difference_with(other);
        out
    }

    /// True iff the sets share no element.
    pub fn is_disjoint(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & b == 0)
    }

    /// True iff `self ⊆ other`.
    pub fn is_subset(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// Iterate set members in ascending order.
    pub fn iter(&self) -> BitIter<'_> {
        BitIter { words: &self.words, word_idx: 0, current: self.words.first().copied().unwrap_or(0) }
    }

    /// Members as a `Vec<usize>` in ascending order.
    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }

    /// Build from an iterator of members.
    pub fn from_iter<I: IntoIterator<Item = usize>>(len: usize, items: I) -> Self {
        let mut s = BitSet::new(len);
        for i in items {
            s.insert(i);
        }
        s
    }

    /// First member, if any.
    pub fn first(&self) -> Option<usize> {
        self.iter().next()
    }
}

impl std::fmt::Debug for BitSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_set().entries(self.iter()).finish()
    }
}

/// Ascending iterator over the members of a [`BitSet`].
pub struct BitIter<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for BitIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.current == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
        let bit = self.current.trailing_zeros() as usize;
        self.current &= self.current - 1;
        Some((self.word_idx << 6) | bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains() {
        let mut s = BitSet::new(130);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(64);
        s.insert(129);
        assert!(s.contains(0) && s.contains(64) && s.contains(129));
        assert_eq!(s.count(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.to_vec(), vec![0, 129]);
    }

    #[test]
    fn set_algebra() {
        let a = BitSet::from_iter(100, [1, 5, 80]);
        let b = BitSet::from_iter(100, [5, 80, 99]);
        assert_eq!(a.union(&b).to_vec(), vec![1, 5, 80, 99]);
        assert_eq!(a.intersection(&b).to_vec(), vec![5, 80]);
        assert_eq!(a.difference(&b).to_vec(), vec![1]);
        assert!(a.intersection(&b).is_subset(&a));
        assert!(!a.is_disjoint(&b));
        assert!(BitSet::from_iter(100, [1]).is_disjoint(&BitSet::from_iter(100, [2])));
    }

    #[test]
    fn iter_empty_and_full_words() {
        let s = BitSet::new(200);
        assert_eq!(s.iter().count(), 0);
        let f = BitSet::from_iter(200, 0..200);
        assert_eq!(f.count(), 200);
        assert_eq!(f.iter().count(), 200);
    }
}
