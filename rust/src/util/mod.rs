//! Small shared substrates: bitsets, parallel helpers, timers, stats.

pub mod bitset;
pub mod par;
pub mod stats;
pub mod timer;

pub use bitset::BitSet;
pub use par::{num_threads, par_chunk_map, par_for_each_index, par_map_index};
pub use stats::{mean, std_dev, Summary};
pub use timer::Timer;
