//! Small shared substrates: bitsets, parallel helpers, timers, stats.
//!
//! Timing moved to [`crate::obs::clock`]; `util::timer` / [`Timer`]
//! remain as compatibility re-exports.

pub mod bitset;
pub mod par;
pub mod stats;
pub mod timer;

pub use bitset::BitSet;
pub use par::{num_threads, par_chunk_map, par_for_each_index, par_map_index};
pub use stats::{mean, std_dev, Summary};
pub use timer::Timer;

/// Oversized-frame guard shared by every length-prefixed wire in the
/// crate (the ring transport and the query server): a corrupt or
/// hostile length prefix must be rejected with one wording everywhere,
/// before any buffer is allocated for it. `direction` is `"outgoing"`
/// or `"incoming"`.
pub fn ensure_frame_len(direction: &str, len: u32, cap: u32) -> anyhow::Result<()> {
    anyhow::ensure!(len <= cap, "{direction} frame of {len} bytes exceeds cap {cap}");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn frame_len_guard_wording() {
        assert!(super::ensure_frame_len("incoming", 10, 10).is_ok());
        let e = super::ensure_frame_len("incoming", 11, 10).unwrap_err();
        assert_eq!(format!("{e}"), "incoming frame of 11 bytes exceeds cap 10");
    }
}
