"""L2: the JAX compute graph exported to the Rust coordinator.

`similarity_model` is the whole of stage-1's dense math: the pairwise
BDeu similarity matrix (L1 Pallas kernel) plus the per-variable
empty-graph BDeu local scores (plain jnp — a cheap marginal count).
Both lower into one HLO module; `aot.py` serializes it as HLO *text*
per shape-config, and `rust/src/runtime` loads + executes it via PJRT.

Python never runs on the learning path: this file is build-time only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import pairwise_bdeu


def empty_scores(data, cards, ess, *, r_max: int):
    """Per-variable BDeu local score with no parents, (n,) f32.

    Pure jnp: marginal counts via one-hot sum. Padded instances
    (state >= r_max) drop out of the counts; padded variables
    (card = 1, state = r_max) score lgamma(ess) - lgamma(ess) = 0.
    """
    states = jax.lax.broadcasted_iota(jnp.int32, (1, 1, r_max), 2)
    counts = (data[:, :, None] == states).astype(jnp.float32).sum(axis=1)  # (n, r)
    lgamma = jax.lax.lgamma
    a_cell = (ess / cards)[:, None]  # (n, 1)
    n_tot = counts.sum(axis=1)
    cell = (lgamma(counts + a_cell) - lgamma(a_cell)).sum(axis=1)
    return lgamma(jnp.full_like(n_tot, ess)) - lgamma(n_tot + ess) + cell


@functools.partial(jax.jit, static_argnames=("r_max", "block"))
def similarity_model(data, cards, ess, *, r_max: int, block: int = 8):
    """The exported computation.

    Args:
      data:  (n, m) int32 dataset (variables x instances).
      cards: (n,) f32 cardinalities.
      ess:   (1, 1) f32 BDeu equivalent sample size.

    Returns:
      (S, empty): (n, n) f32 similarity matrix, (n,) f32 empty scores.
    """
    s = pairwise_bdeu(data, cards, ess, r_max=r_max, block=block)
    e = empty_scores(data, cards, ess[0, 0], r_max=r_max)
    return s, e
