"""AOT export: lower `similarity_model` to HLO text per shape-config.

HLO *text* (NOT `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run as:  cd python && python -m compile.aot --out-dir ../artifacts
Emits one `similarity_<name>.hlo.txt` per config plus `manifest.txt`
(`name n m r_max block file` per line) which rust/src/runtime/artifacts.rs
uses to pick the smallest config a dataset fits into (with padding).
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import similarity_model

# (name, n, m, r_max, block). n must be a multiple of block. Sizes chosen
# so `make artifacts` stays fast while covering the bench scales; the
# paper-scale configs (n up to 1088 >= munin's 1041) are exported too.
CONFIGS = [
    ("tiny", 32, 256, 4, 8),
    ("small", 128, 1024, 8, 8),
    ("medium", 256, 5000, 8, 8),
    ("large", 512, 5000, 8, 8),
    ("xl", 1088, 5000, 8, 8),
    ("wide", 1088, 5000, 22, 8),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(n: int, m: int, r_max: int, block: int):
    import jax.numpy as jnp

    data = jax.ShapeDtypeStruct((n, m), jnp.int32)
    cards = jax.ShapeDtypeStruct((n,), jnp.float32)
    ess = jax.ShapeDtypeStruct((1, 1), jnp.float32)
    fn = lambda d, c, e: similarity_model(d, c, e, r_max=r_max, block=block)
    return jax.jit(fn).lower(data, cards, ess)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs", default=None, help="comma-separated config names (default: all)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    wanted = set(args.configs.split(",")) if args.configs else None
    manifest_lines = []
    for name, n, m, r_max, block in CONFIGS:
        if wanted is not None and name not in wanted:
            continue
        fname = f"similarity_{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        text = to_hlo_text(lower_config(n, m, r_max, block))
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {n} {m} {r_max} {block} {fname}")
        print(f"wrote {path}: n={n} m={m} r_max={r_max} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
