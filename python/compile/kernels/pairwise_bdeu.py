"""L1 Pallas kernel: blocked pairwise BDeu similarity.

Computes the n x n matrix  S[i, j] = BDeu(X_i <- X_j) - BDeu(X_i <- {})
(Eq. 4 of the paper) over a discrete dataset, the hot-spot of cGES's
edge-partitioning stage (and the seed scores of the first FES sweep).

Kernel design (TPU-shaped, run under interpret=True on CPU):
  * grid over (i-block, j-block) of size B x B variable pairs;
  * the two (B, m) int32 row-blocks of the dataset live in VMEM;
  * one-hot expansion happens on the fly via broadcasted-iota comparison
    (HBM holds int32 states, never the one-hot tensor);
  * the (B*r, m) @ (m, B*r) contingency contraction is a single
    MXU-shaped dot_general in f32;
  * the (B, B, r, r) count block is scored in-register with lgamma and
    only the (B, B) score block is written back to HBM.

Padding conventions (see runtime/artifacts.rs on the Rust side):
  * padded instances carry state value >= r_max  -> one-hot rows are all
    zero -> contribute nothing to any count;
  * padded variables carry card = 1 and state value r_max -> all counts
    zero -> their similarity entries are exactly 0.0.

BDeu bookkeeping: the sums formally range over the padded r_max states,
but a zero-count cell contributes lgamma(a) - lgamma(a) = 0 exactly, and
a zero-count parent configuration contributes 0 likewise, so no masking
is required as long as the *hyperparameters* use the true cardinalities
(taken from the `cards` input).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8


def _score_block(counts, cx, cy, ess):
    """Score a (B, B, r, r) count block -> (B, B) BDeu deltas.

    counts[bi, bj, a, b] = #{t : X_i = a, X_j = b}; child axis is `a`.
    cx, cy: (B,) f32 true cardinalities of the child / parent rows.
    """
    lgamma = jax.lax.lgamma
    r_x = cx[:, None]  # (B, 1) child cardinalities
    q_y = cy[None, :]  # (1, B) parent-config counts (single discrete parent)

    a_cell = ess / (r_x * q_y)  # Dirichlet cell hyperparameter  (B, B)
    a_cfg = ess / q_y  # per-parent-config hyperparameter     (B, B)
    a_marg = ess / r_x  # empty-graph cell hyperparameter       (B, B)

    nj = counts.sum(axis=2)  # (B, B, r)  per parent state
    na = counts.sum(axis=3)  # (B, B, r)  child marginals
    n = nj.sum(axis=2)  # (B, B)     total (valid) instances

    # BDeu(X <- Y): sum over parent configs + cells. Zero-count entries
    # cancel exactly, so summing over the padded r range is sound.
    cfg_term = (lgamma(a_cfg[..., None]) - lgamma(nj + a_cfg[..., None])).sum(axis=2)
    cell_term = (
        lgamma(counts + a_cell[..., None, None]) - lgamma(a_cell[..., None, None])
    ).sum(axis=(2, 3))
    score_xy = cfg_term + cell_term

    # BDeu(X <- {}): single configuration.
    marg_term = (lgamma(na + a_marg[..., None]) - lgamma(a_marg[..., None])).sum(axis=2)
    score_x0 = lgamma(jnp.full_like(n, ess)) - lgamma(n + ess) + marg_term

    return score_xy - score_x0


def _kernel(x_ref, y_ref, cx_ref, cy_ref, ess_ref, o_ref, *, r_max: int, block: int):
    b, m = x_ref.shape
    x = x_ref[...]  # (B, m) int32 child rows
    y = y_ref[...]  # (B, m) int32 parent rows

    # On-the-fly one-hot: (B, r, m) f32. States >= r_max (padding) match
    # nothing and vanish from every count.
    states = jax.lax.broadcasted_iota(jnp.int32, (1, r_max, 1), 1)
    x_oh = (x[:, None, :] == states).astype(jnp.float32)
    y_oh = (y[:, None, :] == states).astype(jnp.float32)

    # MXU-shaped contraction over the instance axis:
    # (B*r, m) @ (m, B*r) -> (B*r, B*r).
    flat = jax.lax.dot_general(
        x_oh.reshape(b * r_max, m),
        y_oh.reshape(b * r_max, m),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    counts = flat.reshape(b, r_max, b, r_max).transpose(0, 2, 1, 3)  # (B,B,r,r)

    s = _score_block(counts, cx_ref[...], cy_ref[...], ess_ref[0, 0])
    o_ref[...] = s.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("r_max", "block"))
def pairwise_bdeu(data, cards, ess, *, r_max: int, block: int = DEFAULT_BLOCK):
    """Pairwise BDeu similarity matrix.

    Args:
      data:  (n, m) int32, states in [0, cards[i]) — or >= r_max for padding.
      cards: (n,) f32 true cardinalities (1 for padded variables).
      ess:   (1, 1) f32 equivalent sample size (eta).
      r_max: static max cardinality (one-hot width).
      block: static variable-block size B; n must be a multiple of B.

    Returns:
      (n, n) f32 with S[i, j] = BDeu(X_i <- X_j) - BDeu(X_i <- {}).
    """
    n, m = data.shape
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    nb = n // block

    kernel = functools.partial(_kernel, r_max=r_max, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, m), lambda i, j: (i, 0)),  # child rows
            pl.BlockSpec((block, m), lambda i, j: (j, 0)),  # parent rows
            pl.BlockSpec((block,), lambda i, j: (i,)),  # child cards
            pl.BlockSpec((block,), lambda i, j: (j,)),  # parent cards
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # ess scalar
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(data, data, cards, cards, ess)
