"""Pure-numpy/scipy oracle for the pairwise BDeu similarity kernel.

Deliberately written as a direct transcription of the BDeu definition
(Eq. 3 of the paper) with explicit per-pair contingency tables, sharing
no code with the Pallas kernel. Used by pytest/hypothesis as the
correctness reference, and mirrored by the Rust fallback
(`score::pairwise`) which is cross-checked against the same fixtures.
"""

from __future__ import annotations

import numpy as np
from scipy.special import gammaln as lg


def pair_contingency(data, r_max):
    """(n, n, r, r) contingency tensor: N[i, j, a, b] = #{t: X_i=a, X_j=b}.

    States >= r_max (padding) fall outside the one-hot range and are
    dropped, matching the kernel's padding convention.
    """
    onehot = (data[:, :, None] == np.arange(r_max)[None, None, :]).astype(np.float64)
    # (n, m, r) -> N[i, j, a, b] = sum_t onehot[i, t, a] * onehot[j, t, b]
    return np.einsum("ita,jtb->ijab", onehot, onehot)


def bdeu_family(counts_ab, r_child, q_parent, ess):
    """BDeu local score of child with a single discrete parent.

    counts_ab: (r, r) child-state x parent-state counts (padded with 0).
    """
    a_cell = ess / (r_child * q_parent)
    a_cfg = ess / q_parent
    score = 0.0
    for b in range(counts_ab.shape[1]):
        nj = counts_ab[:, b].sum()
        score += lg(a_cfg) - lg(nj + a_cfg)
        for a in range(counts_ab.shape[0]):
            score += lg(counts_ab[a, b] + a_cell) - lg(a_cell)
    return score


def bdeu_empty(counts_a, r_child, ess):
    """BDeu local score of child with no parents."""
    a_cell = ess / r_child
    n = counts_a.sum()
    score = lg(ess) - lg(n + ess)
    for a in range(counts_a.shape[0]):
        score += lg(counts_a[a] + a_cell) - lg(a_cell)
    return score


def pairwise_bdeu_ref(data, cards, ess, r_max):
    """Reference (n, n) similarity matrix in float64."""
    data = np.asarray(data)
    cards = np.asarray(cards, dtype=np.float64)
    n = data.shape[0]
    cont = pair_contingency(data, r_max)
    out = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for j in range(n):
            s_xy = bdeu_family(cont[i, j], cards[i], cards[j], ess)
            s_x0 = bdeu_empty(cont[i, j].sum(axis=1), cards[i], ess)
            out[i, j] = s_xy - s_x0
    return out


def empty_scores_ref(data, cards, ess, r_max):
    """Reference per-variable empty-graph BDeu local scores (float64)."""
    data = np.asarray(data)
    n = data.shape[0]
    out = np.zeros(n, dtype=np.float64)
    for i in range(n):
        counts = (data[i][:, None] == np.arange(r_max)[None, :]).sum(axis=0)
        out[i] = bdeu_empty(counts.astype(np.float64), float(cards[i]), ess)
    return out
