"""L1: Pallas kernels for the paper's compute hot-spot."""

from .pairwise_bdeu import pairwise_bdeu, DEFAULT_BLOCK  # noqa: F401
