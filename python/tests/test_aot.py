"""AOT export sanity: HLO text emits, parses, and declares the expected
entry computation shapes for every config in the manifest."""

import os
import subprocess
import sys

import pytest

from compile.aot import CONFIGS, lower_config, to_hlo_text


def test_configs_are_well_formed():
    names = [c[0] for c in CONFIGS]
    assert len(set(names)) == len(names), "duplicate config names"
    for name, n, m, r_max, block in CONFIGS:
        assert n % block == 0, f"{name}: n must be a multiple of block"
        assert r_max >= 2 and m > 0


def test_tiny_config_lowers_to_hlo_text():
    text = to_hlo_text(lower_config(16, 64, 3, 8))
    assert "ENTRY" in text
    assert "f32[16,16]" in text  # S output
    assert "s32[16,64]" in text  # data input


def test_export_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--configs", "tiny"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 1
    name, n, m, r, block, fname = manifest[0].split()
    assert name == "tiny" and (out / fname).exists()


def test_paper_scale_configs_cover_domains():
    """The exported configs must cover the paper's three domains
    (link 724, pigs 441, munin 1041 vars; max card 21; 5000 rows)."""
    def fits(n, m, r):
        return any(cn >= n and cm >= m and cr >= r for _, cn, cm, cr, _ in CONFIGS)

    assert fits(441, 5000, 3)   # pigs
    assert fits(724, 5000, 4)   # link
    assert fits(1041, 5000, 21)  # munin
