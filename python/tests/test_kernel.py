"""Kernel vs oracle: the core L1 correctness signal.

The Pallas kernel (f32, blocked, fused count+score) is checked against
the direct-transcription float64 oracle in ref.py. Tolerances absorb
f32 lgamma error accumulated over r^2 terms with counts up to m.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pairwise_bdeu
from compile.kernels.ref import pairwise_bdeu_ref, empty_scores_ref
from compile.model import empty_scores, similarity_model

RTOL = 2e-4
ATOL = 5e-2


def make_data(rng, n, m, r_max, uniform_card=None):
    cards = (
        np.full(n, uniform_card)
        if uniform_card
        else rng.integers(2, r_max + 1, size=n)
    )
    data = np.stack([rng.integers(0, c, size=m) for c in cards]).astype(np.int32)
    return data, cards.astype(np.float32)


def run_kernel(data, cards, ess, r_max, block=8):
    s = pairwise_bdeu(
        jnp.asarray(data),
        jnp.asarray(cards, jnp.float32),
        jnp.full((1, 1), ess, jnp.float32),
        r_max=r_max,
        block=block,
    )
    return np.asarray(s, dtype=np.float64)


def test_matches_oracle_basic():
    rng = np.random.default_rng(0)
    data, cards = make_data(rng, 16, 300, 4)
    s = run_kernel(data, cards, 10.0, 4)
    ref = pairwise_bdeu_ref(data, cards, 10.0, 4)
    np.testing.assert_allclose(s, ref, rtol=RTOL, atol=ATOL)


def test_symmetry_score_equivalence():
    # BDeu is score equivalent: s(i,j) == s(j,i).
    rng = np.random.default_rng(1)
    data, cards = make_data(rng, 24, 500, 5)
    s = run_kernel(data, cards, 4.0, 5)
    np.testing.assert_allclose(s, s.T, rtol=1e-4, atol=1e-2)


def test_correlated_pair_dominates():
    rng = np.random.default_rng(2)
    data, cards = make_data(rng, 8, 800, 3, uniform_card=3)
    data[1] = data[0]  # perfect correlation
    s = run_kernel(data, cards, 10.0, 3)
    off_diag = [s[1, j] for j in range(8) if j not in (0, 1)]
    assert s[1, 0] > max(off_diag)
    assert s[1, 0] > 0


def test_padded_instances_are_ignored():
    rng = np.random.default_rng(3)
    data, cards = make_data(rng, 8, 200, 4)
    padded = np.concatenate(
        [data, np.full((8, 56), 4, dtype=np.int32)], axis=1
    )  # pad state == r_max
    s_plain = run_kernel(data, cards, 10.0, 4)
    s_padded = run_kernel(padded, cards, 10.0, 4)
    np.testing.assert_allclose(s_plain, s_padded, rtol=1e-5, atol=1e-3)


def test_padded_variables_score_zero():
    rng = np.random.default_rng(4)
    data, cards = make_data(rng, 8, 200, 4)
    data[6:] = 4  # pad two variables entirely
    cards[6:] = 1.0
    s = run_kernel(data, cards, 10.0, 4)
    np.testing.assert_allclose(s[6:, :], 0.0, atol=1e-4)
    np.testing.assert_allclose(s[:, 6:][:6], 0.0, atol=1e-4)


def test_block_size_invariance():
    rng = np.random.default_rng(5)
    data, cards = make_data(rng, 16, 250, 4)
    s8 = run_kernel(data, cards, 10.0, 4, block=8)
    s4 = run_kernel(data, cards, 10.0, 4, block=4)
    s16 = run_kernel(data, cards, 10.0, 4, block=16)
    np.testing.assert_allclose(s8, s4, rtol=1e-6, atol=1e-4)
    np.testing.assert_allclose(s8, s16, rtol=1e-6, atol=1e-4)


def test_rejects_bad_block():
    rng = np.random.default_rng(6)
    data, cards = make_data(rng, 12, 100, 3)
    with pytest.raises(ValueError):
        run_kernel(data, cards, 10.0, 3, block=8)  # 12 % 8 != 0


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    m=st.integers(50, 400),
    r_max=st.integers(2, 6),
    ess=st.sampled_from([1.0, 4.0, 10.0]),
    seed=st.integers(0, 10_000),
)
def test_hypothesis_sweep(n_blocks, m, r_max, ess, seed):
    """Property sweep over shapes, arities and ESS: kernel == oracle."""
    rng = np.random.default_rng(seed)
    n = 8 * n_blocks
    data, cards = make_data(rng, n, m, r_max)
    s = run_kernel(data, cards, ess, r_max)
    ref = pairwise_bdeu_ref(data, cards, ess, r_max)
    np.testing.assert_allclose(s, ref, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(50, 300), r_max=st.integers(2, 5), seed=st.integers(0, 9999))
def test_empty_scores_match(m, r_max, seed):
    rng = np.random.default_rng(seed)
    data, cards = make_data(rng, 16, m, r_max)
    e = np.asarray(
        empty_scores(jnp.asarray(data), jnp.asarray(cards), 10.0, r_max=r_max),
        dtype=np.float64,
    )
    ref = empty_scores_ref(data, cards, 10.0, r_max)
    np.testing.assert_allclose(e, ref, rtol=RTOL, atol=ATOL)


def test_similarity_model_tuple():
    rng = np.random.default_rng(7)
    data, cards = make_data(rng, 16, 200, 4)
    s, e = similarity_model(
        jnp.asarray(data),
        jnp.asarray(cards),
        jnp.full((1, 1), 10.0, jnp.float32),
        r_max=4,
    )
    assert s.shape == (16, 16)
    assert e.shape == (16,)
    np.testing.assert_allclose(
        np.asarray(e, np.float64), empty_scores_ref(data, cards, 10.0, 4), rtol=RTOL, atol=ATOL
    )
