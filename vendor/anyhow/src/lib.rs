//! Vendored minimal drop-in for the `anyhow` API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no network and no crates.io registry, so
//! the real `anyhow` cannot be fetched; this crate keeps every
//! `use anyhow::...` line in the tree compiling unchanged. Errors are
//! stored as a chain of messages (context outermost), so `{e}` prints
//! the outermost context, `{e:#}` prints the full `outer: inner: root`
//! chain, and `Debug` (what `fn main() -> Result<()>` prints) shows a
//! `Caused by:` list like the real crate.

use std::fmt;

/// `Result` with a defaulted boxed-message error, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Chain-of-messages error value. Cheap, `Send + Sync`, built either
/// from any `std::error::Error` (via `?`) or from the `anyhow!` macro.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error { msg: msgs.pop().expect("at least one message"), source: None };
        while let Some(m) = msgs.pop() {
            err = Error { msg: m, source: Some(Box::new(err)) };
        }
        err
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        assert_eq!(e.chain(), vec!["reading manifest", "missing file"]);
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails with {}", 42);
        }
        assert_eq!(format!("{}", bails(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", bails(true).unwrap_err()), "always fails with 42");
    }

    #[test]
    fn debug_shows_cause_list() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}
