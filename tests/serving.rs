//! Concurrent serving, joint MAP and batch equivalence tests.
//!
//! The contracts under test: (1) the compiled model is genuinely
//! shareable — N simultaneous TCP clients get answers byte-identical
//! to a single-threaded server; (2) `joint_map` equals brute-force
//! joint argmax enumeration at 1e-9; (3) a `batch` request equals
//! issuing its sub-queries individually; (4) the scratch
//! collect-message cache never leaks evidence between queries; (5) the
//! frame cap is configurable and the shutdown sentinel drains the
//! pool; (6) after TCP traffic the `{"type": "stats"}` endpoint
//! reports non-zero latency buckets and an attached tracer holds the
//! serve and jointree spans for that traffic.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};

use cges::bn::{generate, DiscreteBn, NetGenConfig};
use cges::engine::{CompiledModel, ServeConfig, Server, SharedEngine};
use cges::infer::json::Json;
use cges::infer::EngineConfig;
use cges::model::{bundle_from_bytes, bundle_to_bytes, Bundle, BundleMeta};
use cges::obs::Tracer;

fn small_cfg(nodes: usize, edges: usize) -> NetGenConfig {
    NetGenConfig { nodes, edges, max_parents: 3, card_range: (2, 3), locality: 0, alpha: 0.8 }
}

/// Deterministic distinct evidence vars with in-range states (same
/// recipe as tests/inference.rs).
fn evidence_for(seed: u64, bn: &DiscreteBn, n_obs: usize) -> Vec<(usize, usize)> {
    let n = bn.n();
    (0..n_obs)
        .map(|i| {
            let v = ((seed as usize) * 3 + i * 5) % n;
            let s = ((seed as usize) + i) % bn.cards[v] as usize;
            (v, s)
        })
        .filter({
            let mut seen: Vec<usize> = Vec::new();
            move |&(v, _)| {
                if seen.contains(&v) {
                    false
                } else {
                    seen.push(v);
                    true
                }
            }
        })
        .collect()
}

/// Probability of one complete assignment under `bn`.
fn joint_prob(bn: &DiscreteBn, states: &[u8]) -> f64 {
    let mut p = 1.0f64;
    for v in 0..bn.n() {
        let cfg = bn.parent_config(v, states, &bn.cards);
        p *= bn.cpts[v].row(cfg)[states[v] as usize];
    }
    p
}

/// Brute-force joint MAP: enumerate every complete assignment
/// consistent with the evidence, keep the strict maximum. (Ties would
/// go to the first assignment enumerated; the generated CPTs are
/// generic, so the tested networks have a unique maximizer and the
/// engine's per-clique tie rule never comes into play.)
fn brute_force_map(bn: &DiscreteBn, evidence: &[(usize, usize)]) -> (Vec<usize>, f64) {
    let n = bn.n();
    let cards: Vec<usize> = bn.cards.iter().map(|&c| c as usize).collect();
    let mut states = vec![0u8; n];
    let mut best_states: Vec<usize> = vec![0; n];
    let mut best_p = -1.0f64;
    let mut done = false;
    while !done {
        if evidence.iter().all(|&(v, s)| states[v] as usize == s) {
            let p = joint_prob(bn, &states);
            if p > best_p {
                best_p = p;
                best_states = states.iter().map(|&s| s as usize).collect();
            }
        }
        done = true;
        for (st, &c) in states.iter_mut().zip(&cards) {
            *st += 1;
            if (*st as usize) < c {
                done = false;
                break;
            }
            *st = 0;
        }
    }
    (best_states, best_p)
}

fn send_frame(writer: &mut impl Write, payload: &str) {
    let bytes = payload.as_bytes();
    writer.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
    writer.write_all(bytes).unwrap();
    writer.flush().unwrap();
}

fn recv_frame(reader: &mut impl Read) -> String {
    let mut len_bytes = [0u8; 4];
    reader.read_exact(&mut len_bytes).unwrap();
    let len = u32::from_le_bytes(len_bytes) as usize;
    let mut payload = vec![0u8; len];
    reader.read_exact(&mut payload).unwrap();
    String::from_utf8(payload).unwrap()
}

/// JSON evidence object text for a list of (var, state) pairs.
fn evidence_json(bn: &DiscreteBn, evidence: &[(usize, usize)]) -> String {
    let cells: Vec<String> =
        evidence.iter().map(|&(v, s)| format!("\"{}\": {s}", bn.names[v])).collect();
    format!("{{{}}}", cells.join(", "))
}

#[test]
fn engine_types_are_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<CompiledModel>();
    assert_send_sync::<SharedEngine>();
    assert_send_sync::<Server>();
}

#[test]
fn scratch_cache_never_leaks_evidence_between_queries() {
    // One long-lived scratch walking through evidence sets that grow,
    // shrink, repeat and permute must answer exactly like a fresh
    // scratch per query (the cache is invisible except in speed).
    let bn = generate(&small_cfg(9, 12), 7);
    let model = CompiledModel::compile(&bn).unwrap();
    let mut warm = model.new_scratch();

    let mut sequences: Vec<Vec<(usize, usize)>> = Vec::new();
    for seed in 0..8u64 {
        for n_obs in [0usize, 1, 2, 3, 2, 0, 3] {
            sequences.push(evidence_for(seed, &bn, n_obs));
        }
    }
    // Repeat a set twice in a row (full cache hit) and reversed
    // spellings of the same set (canonicalization).
    let dup = evidence_for(3, &bn, 3);
    sequences.push(dup.clone());
    sequences.push(dup.clone());
    let mut rev = dup;
    rev.reverse();
    sequences.push(rev);

    for (i, evidence) in sequences.iter().enumerate() {
        let mut fresh = model.new_scratch();
        let want = model.marginals(&mut fresh, evidence).unwrap();
        let got = model.marginals(&mut warm, evidence).unwrap();
        assert!(
            (got.log_evidence - want.log_evidence).abs() < 1e-12,
            "step {i}: log evidence {} vs {}",
            got.log_evidence,
            want.log_evidence
        );
        for v in 0..bn.n() {
            for (a, b) in got.marginal(v).iter().zip(want.marginal(v)) {
                assert!((a - b).abs() < 1e-12, "step {i} var {v}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn responses_unchanged_by_blocked_kernels() {
    // Before/after regression for the blocked-kernel rework: the
    // planned, fused, allocation-free path must reproduce the retained
    // scalar reference engine (`marginals_reference` /
    // `joint_map_reference`, the verbatim pre-rework implementation)
    // bit-for-bit. Responses are formatted from exactly these f64s by
    // deterministic code, so bit-equality here is byte-equality of the
    // served JSON.
    for seed in [3u64, 8, 21] {
        let bn = generate(&small_cfg(10, 14), seed);
        let model = CompiledModel::compile(&bn).unwrap();
        let mut warm = model.new_scratch();
        for n_obs in [0usize, 1, 2, 3, 0, 2] {
            let evidence = evidence_for(seed, &bn, n_obs);
            let got = model.marginals(&mut warm, &evidence).unwrap();
            let want = model.marginals_reference(&evidence).unwrap();
            assert_eq!(
                got.log_evidence.to_bits(),
                want.log_evidence.to_bits(),
                "seed {seed} obs {n_obs}: log evidence {} vs {}",
                got.log_evidence,
                want.log_evidence
            );
            for v in 0..bn.n() {
                for (i, (a, b)) in got.marginal(v).iter().zip(want.marginal(v)).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "seed {seed} obs {n_obs} var {v} state {i}: {a} vs {b}"
                    );
                }
            }
            let (ga, gl) = model.joint_map(&mut warm, &evidence).unwrap();
            let (wa, wl) = model.joint_map_reference(&evidence).unwrap();
            assert_eq!(ga, wa, "seed {seed} obs {n_obs}: joint MAP assignment");
            assert_eq!(gl.to_bits(), wl.to_bits(), "seed {seed} obs {n_obs}: log MAP");
        }
    }
}

#[test]
fn warm_scratch_survives_zero_probability_bails() {
    // The arena rework moves message buffers out of the scratch with
    // mem::take during propagation; every zero-probability bail must
    // put them back, or the next query on the same scratch would hit
    // a zero-length buffer. Drive contradictory evidence (probability
    // zero on a multi-clique model) between normal queries and pin
    // the answers to a fresh-scratch reference.
    let bn = generate(&small_cfg(10, 14), 6);
    let model = CompiledModel::compile(&bn).unwrap();
    let mut warm = model.new_scratch();
    let contradiction = vec![(0usize, 0usize), (0, 1)];
    for n_obs in [0usize, 2, 3, 1] {
        assert!(model.marginals(&mut warm, &contradiction).is_err());
        assert!(model.joint_map(&mut warm, &contradiction).is_err());
        let evidence = evidence_for(5, &bn, n_obs);
        let got = model.marginals(&mut warm, &evidence).unwrap();
        let want = model.marginals_reference(&evidence).unwrap();
        assert_eq!(got.log_evidence.to_bits(), want.log_evidence.to_bits(), "obs {n_obs}");
        for v in 0..bn.n() {
            for (a, b) in got.marginal(v).iter().zip(want.marginal(v)) {
                assert_eq!(a.to_bits(), b.to_bits(), "obs {n_obs} var {v}: {a} vs {b}");
            }
        }
        let (ga, gl) = model.joint_map(&mut warm, &evidence).unwrap();
        let (wa, wl) = model.joint_map_reference(&evidence).unwrap();
        assert_eq!(ga, wa, "obs {n_obs}: joint MAP after bail");
        assert_eq!(gl.to_bits(), wl.to_bits(), "obs {n_obs}: log MAP after bail");
    }
}

#[test]
fn warm_start_is_bit_identical_to_cold_compile_and_skips_collect() {
    // The bundle warm-start contract: a model built from a shipped
    // artifact (through the binary codec, as serving would consume it)
    // answers byte-for-byte like a cold compile of the same network —
    // across an evidence walk, for marginals and joint MAP — while its
    // first evidence-free query recomputes zero collect messages.
    for seed in [4u64, 19, 33] {
        let bn = generate(&small_cfg(10, 14), seed);
        let meta =
            BundleMeta { producer: "pin".into(), rounds: 1, score: -1.0, ess: 1.0 };
        let bundle = Bundle::calibrated_within(bn.clone(), meta, u64::MAX);
        assert!(bundle.has_potentials(), "seed {seed}: small jointree must calibrate");
        let decoded = bundle_from_bytes(&bundle_to_bytes(&bundle)).unwrap();

        let warm = CompiledModel::from_bundle(&decoded).unwrap();
        assert!(warm.is_warm_started(), "seed {seed}");
        let cold = CompiledModel::compile(&bn).unwrap();
        let mut ws = warm.new_scratch();
        let mut cs = cold.new_scratch();

        let a = warm.marginals(&mut ws, &[]).unwrap();
        let b = cold.marginals(&mut cs, &[]).unwrap();
        assert_eq!(
            ws.collect_recomputes(),
            0,
            "seed {seed}: warm start recomputed collect messages"
        );
        assert!(cs.collect_recomputes() > 0, "seed {seed}: probe is live");
        assert_eq!(a.log_evidence.to_bits(), b.log_evidence.to_bits(), "seed {seed}");
        for v in 0..bn.n() {
            for (x, y) in a.marginal(v).iter().zip(b.marginal(v)) {
                assert_eq!(x.to_bits(), y.to_bits(), "seed {seed} var {v}");
            }
        }

        for n_obs in [1usize, 2, 3, 0, 2] {
            let evidence = evidence_for(seed, &bn, n_obs);
            let a = warm.marginals(&mut ws, &evidence).unwrap();
            let b = cold.marginals(&mut cs, &evidence).unwrap();
            assert_eq!(a.log_evidence.to_bits(), b.log_evidence.to_bits(), "seed {seed}");
            for v in 0..bn.n() {
                for (x, y) in a.marginal(v).iter().zip(b.marginal(v)) {
                    assert_eq!(x.to_bits(), y.to_bits(), "seed {seed} obs {n_obs} var {v}");
                }
            }
            let (xa, xl) = warm.joint_map(&mut ws, &evidence).unwrap();
            let (ya, yl) = cold.joint_map(&mut cs, &evidence).unwrap();
            assert_eq!(xa, ya, "seed {seed} obs {n_obs}: joint MAP");
            assert_eq!(xl.to_bits(), yl.to_bits(), "seed {seed} obs {n_obs}: log MAP");
        }

        // Whole served responses (the f64s formatted by deterministic
        // code) are therefore byte-identical too.
        let cfg = EngineConfig::default();
        let warm_srv = Server::from_bundle(&decoded, &cfg, ServeConfig::default()).unwrap();
        assert!(warm_srv.warm_started(), "seed {seed}");
        let cold_srv = Server::new(&bn, &cfg, ServeConfig::default()).unwrap();
        let mut wss = warm_srv.new_scratch();
        let mut css = cold_srv.new_scratch();
        let e2 = evidence_json(&bn, &evidence_for(seed, &bn, 2));
        for req in [
            r#"{"id": 1, "type": "marginal"}"#.to_string(),
            format!(r#"{{"id": 2, "type": "marginal", "evidence": {e2}}}"#),
            format!(r#"{{"id": 3, "type": "joint_map", "evidence": {e2}}}"#),
            format!(r#"{{"id": 4, "type": "map", "evidence": {e2}}}"#),
        ] {
            assert_eq!(
                warm_srv.handle(&mut wss, &req),
                cold_srv.handle(&mut css, &req),
                "seed {seed}: served bytes diverged on {req}"
            );
        }

        // A foreign fingerprint must fall back to a cold compile and
        // still serve identical bytes.
        let mut foreign = decoded.clone();
        foreign.potentials.as_mut().unwrap().fingerprint ^= 0xF00D;
        let fallback = CompiledModel::from_bundle(&foreign).unwrap();
        assert!(!fallback.is_warm_started(), "seed {seed}");
        let mut fs = fallback.new_scratch();
        let a = fallback.marginals(&mut fs, &[]).unwrap();
        assert_eq!(a.log_evidence.to_bits(), b.log_evidence.to_bits(), "seed {seed}");
    }
}

#[test]
fn joint_map_matches_brute_force_argmax() {
    for seed in 0..6u64 {
        let bn = generate(&small_cfg(8, 11), seed ^ 0x3A);
        let model = CompiledModel::compile(&bn).unwrap();
        let mut scratch = model.new_scratch();
        for n_obs in 0..3usize {
            let evidence = evidence_for(seed, &bn, n_obs);
            let (want_states, want_p) = brute_force_map(&bn, &evidence);
            let (got_states, got_log) = model.joint_map(&mut scratch, &evidence).unwrap();
            assert!(
                (got_log - want_p.ln()).abs() < 1e-9,
                "seed {seed} obs {n_obs}: log MAP {got_log} vs {}",
                want_p.ln()
            );
            // The returned assignment achieves the maximum...
            let got_u8: Vec<u8> = got_states.iter().map(|&s| s as u8).collect();
            let got_p = joint_prob(&bn, &got_u8);
            assert!(
                (got_p - want_p).abs() <= 1e-9 * want_p.max(1e-300),
                "seed {seed} obs {n_obs}: P(assignment) {got_p} vs max {want_p}"
            );
            // ...and respects the evidence.
            for &(v, s) in &evidence {
                assert_eq!(got_states[v], s, "seed {seed}: evidence var {v}");
            }
            // Generic tables have no exact ties, so the argmax itself
            // must agree with enumeration.
            assert_eq!(got_states, want_states, "seed {seed} obs {n_obs}");
        }
    }
}

#[test]
fn concurrent_tcp_clients_match_single_threaded_answers() {
    const CLIENTS: usize = 4;
    const QUERIES: usize = 8;

    let bn = generate(&small_cfg(9, 12), 5);
    let cfg = EngineConfig::default();

    // Per-client request scripts mixing every query type.
    let requests: Vec<Vec<String>> = (0..CLIENTS)
        .map(|c| {
            (0..QUERIES)
                .map(|q| {
                    let evidence = evidence_for((c * QUERIES + q) as u64, &bn, q % 3);
                    let ev = evidence_json(&bn, &evidence);
                    match q % 4 {
                        0 => format!(r#"{{"id": {q}, "type": "marginal", "evidence": {ev}}}"#),
                        1 => format!(
                            r#"{{"id": {q}, "type": "map", "targets": ["{}"], "evidence": {ev}}}"#,
                            bn.names[q % bn.n()]
                        ),
                        2 => format!(r#"{{"id": {q}, "type": "joint_map", "evidence": {ev}}}"#),
                        _ => format!(
                            r#"{{"id": {q}, "type": "batch", "queries": [{{"id": 0, "evidence": {ev}}}, {{"id": 1, "type": "joint_map"}}]}}"#
                        ),
                    }
                })
                .collect()
        })
        .collect();

    // Single-threaded reference answers.
    let reference = Server::new(&bn, &cfg, ServeConfig::default()).unwrap();
    let mut ref_scratch = reference.new_scratch();
    let expected: Vec<Vec<String>> = requests
        .iter()
        .map(|qs| qs.iter().map(|q| reference.handle(&mut ref_scratch, q)).collect())
        .collect();

    let server =
        Server::new(&bn, &cfg, ServeConfig { threads: CLIENTS, ..Default::default() }).unwrap();
    assert_eq!(server.engine_name(), "jointree");
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let server = &server;
        s.spawn(move || server.serve_tcp(&listener, Some(CLIENTS)).unwrap());
        let mut clients = Vec::new();
        for c in 0..CLIENTS {
            let reqs = &requests[c];
            let exps = &expected[c];
            clients.push(s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                for (req, want) in reqs.iter().zip(exps) {
                    send_frame(&mut writer, req);
                    let got = recv_frame(&mut reader);
                    assert_eq!(&got, want, "client {c} diverged from single-threaded answer");
                }
            }));
        }
        for h in clients {
            h.join().unwrap();
        }
    });
}

#[test]
fn batch_answers_match_singleton_answers() {
    let bn = generate(&small_cfg(9, 13), 11);
    let cfg = EngineConfig::default();

    // Sub-queries with heavy evidence-prefix sharing: duplicates,
    // permuted spellings of one set, and a failing query mixed in.
    let e2 = evidence_for(4, &bn, 2);
    let mut e2_rev = e2.clone();
    e2_rev.reverse();
    let e3 = evidence_for(4, &bn, 3);
    let singles = [
        format!(r#"{{"id": 0, "type": "marginal", "evidence": {}}}"#, evidence_json(&bn, &e2)),
        format!(r#"{{"id": 1, "type": "map", "evidence": {}}}"#, evidence_json(&bn, &e3)),
        format!(r#"{{"id": 2, "type": "marginal", "evidence": {}}}"#, evidence_json(&bn, &e2_rev)),
        format!(r#"{{"id": 3, "type": "joint_map", "evidence": {}}}"#, evidence_json(&bn, &e2)),
        r#"{"id": 4, "type": "marginal", "targets": ["not_a_var"]}"#.to_string(),
        r#"{"id": 5, "type": "marginal"}"#.to_string(),
        format!(r#"{{"id": 6, "type": "marginal", "evidence": {}}}"#, evidence_json(&bn, &e2)),
    ];

    // Individually issued, each on a cold server.
    let expected: Vec<Json> = singles
        .iter()
        .map(|q| {
            let cold = Server::new(&bn, &cfg, ServeConfig::default()).unwrap();
            let mut scratch = cold.new_scratch();
            Json::parse(&cold.handle(&mut scratch, q)).unwrap()
        })
        .collect();

    let batch = format!(r#"{{"id": 99, "type": "batch", "queries": [{}]}}"#, singles.join(", "));
    let server = Server::new(&bn, &cfg, ServeConfig::default()).unwrap();
    let mut scratch = server.new_scratch();
    let v = Json::parse(&server.handle(&mut scratch, &batch)).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(99));
    let results = v.get("results").and_then(Json::as_array).unwrap();
    assert_eq!(results.len(), singles.len());
    for (i, (got, want)) in results.iter().zip(&expected).enumerate() {
        assert_eq!(got, want, "batch slot {i} diverged from its singleton answer");
    }
}

#[test]
fn frame_cap_is_configurable_and_shared_wording() {
    let bn = generate(&small_cfg(6, 8), 2);
    let server = Server::new(
        &bn,
        &EngineConfig::default(),
        ServeConfig { max_frame_bytes: 256, ..Default::default() },
    )
    .unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let server = &server;
        s.spawn(move || server.serve_tcp(&listener, Some(2)).unwrap());

        // Connection 1: an oversized length prefix is rejected before
        // the payload is read; the connection dies, the server lives.
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut writer = BufWriter::new(stream.try_clone().unwrap());
            writer.write_all(&1024u32.to_le_bytes()).unwrap();
            writer.flush().unwrap();
            let mut reader = BufReader::new(stream);
            let mut buf = [0u8; 4];
            // Server closes without answering.
            assert!(reader.read_exact(&mut buf).is_err());
        }

        // Connection 2: under the cap still answers.
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            send_frame(&mut writer, r#"{"id": 1, "type": "map"}"#);
            let v = Json::parse(&recv_frame(&mut reader)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        }
    });
}

#[test]
fn shutdown_sentinel_drains_the_pool() {
    let bn = generate(&small_cfg(6, 8), 9);
    let server = Server::new(
        &bn,
        &EngineConfig::default(),
        ServeConfig { threads: 2, ..Default::default() },
    )
    .unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let server = &server;
        let handle = s.spawn(move || server.serve_tcp(&listener, None).unwrap());

        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        // A real query first, then the sentinel.
        send_frame(&mut writer, r#"{"id": 1}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        send_frame(&mut writer, r#"{"id": 2, "type": "shutdown"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("shutdown").and_then(Json::as_bool), Some(true));
        drop(writer);
        drop(reader);

        // serve_tcp(None) returns only because the sentinel latched.
        handle.join().unwrap();
        assert!(server.is_shutting_down());
    });
}

#[test]
fn stats_over_tcp_reports_latency_and_tracer_captures_spans() {
    let bn = generate(&small_cfg(8, 11), 5);
    let mut server = Server::new(
        &bn,
        &EngineConfig::default(),
        ServeConfig { threads: 2, ..Default::default() },
    )
    .unwrap();
    server.set_tracer(Tracer::new(true));
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let server = &server;
        s.spawn(move || server.serve_tcp(&listener, Some(1)).unwrap());

        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        for id in 0..4 {
            send_frame(&mut writer, &format!(r#"{{"id": {id}, "type": "marginal"}}"#));
            let v = Json::parse(&recv_frame(&mut reader)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        }

        // An unconfirmed reset is refused and lands in serve.errors.
        send_frame(&mut writer, r#"{"id": 8, "type": "stats_reset"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));

        // The snapshot reflects the traffic it was part of.
        send_frame(&mut writer, r#"{"id": 9, "type": "stats"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let stats = v.get("stats").expect("stats body");
        let counters = stats.get("counters").expect("counters");
        assert!(counters.get("serve.requests").and_then(Json::as_usize).unwrap() >= 5);
        assert!(counters.get("serve.errors").and_then(Json::as_usize).unwrap() >= 1);
        assert!(counters.get("serve.conns_accepted").and_then(Json::as_usize).unwrap() >= 1);
        let hists = stats.get("histograms").expect("histograms");
        let lat = hists.get("serve.latency_ns").expect("latency histogram");
        assert!(lat.get("count").and_then(Json::as_usize).unwrap() >= 5);
        assert!(lat.get("p50").and_then(Json::as_usize).unwrap() > 0);
        assert!(!lat.get("buckets").and_then(Json::as_array).unwrap().is_empty());
        // Both directions of every exchange were measured.
        let frames = hists.get("serve.frame_bytes").expect("frame-size histogram");
        assert!(frames.get("count").and_then(Json::as_usize).unwrap() >= 10);
    });

    // Every request left a span in its thread's serve lane; the exact
    // engine also traced its jointree passes under the same lane.
    let spans = server.tracer().spans();
    assert!(spans.iter().any(|sp| sp.cat == "serve" && sp.name == "marginal"));
    assert!(spans.iter().any(|sp| sp.cat == "serve" && sp.name == "stats"));
    assert!(spans.iter().any(|sp| sp.cat == "jointree" && sp.name == "collect"));
    assert!(spans.iter().any(|sp| sp.cat == "jointree" && sp.name == "distribute"));
}

/// `{"type":"stats","format":"prometheus"}` over a real framed TCP
/// exchange answers the live registry as Prometheus exposition text
/// (satellite of the distributed-obs PR); the default format stays a
/// structured JSON snapshot, byte-compatible with existing scrapers.
#[test]
fn stats_prometheus_format_over_framed_tcp() {
    let bn = generate(&small_cfg(8, 11), 5);
    let server = Server::new(
        &bn,
        &EngineConfig::default(),
        ServeConfig { threads: 1, ..Default::default() },
    )
    .unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let server = &server;
        s.spawn(move || server.serve_tcp(&listener, Some(1)).unwrap());

        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        send_frame(&mut writer, r#"{"id": 1, "type": "marginal"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));

        send_frame(&mut writer, r#"{"id": 2, "type": "stats", "format": "prometheus"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("format").and_then(Json::as_str), Some("prometheus"));
        let text = v
            .get("stats")
            .and_then(Json::as_str)
            .expect("prometheus stats body is a string");
        assert!(
            text.contains("# TYPE serve_requests counter"),
            "missing counter TYPE line in: {text}"
        );
        assert!(
            text.contains("_bucket{le=\"+Inf\"}"),
            "histogram missing the +Inf cumulative bucket"
        );

        // The default shape is untouched: a structured object.
        send_frame(&mut writer, r#"{"id": 3, "type": "stats"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert!(v.get("format").is_none(), "default stats must not grow a format field");
        let stats = v.get("stats").expect("stats body");
        assert!(stats.get("counters").is_some(), "default stats is the JSON snapshot");
    });
}
