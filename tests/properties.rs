//! Randomized property tests over the DESIGN.md §6 invariants
//! (hand-rolled generator loop — no proptest in the offline registry;
//! failures print the seed for replay).

use std::sync::Arc;

use cges::bn::{forward_sample, generate, netgen::random_dag, read_bif, write_bif, NetGenConfig};
use cges::coordinator::{cges, RingConfig, RingMode};
use cges::data::Dataset;
use cges::fusion::{fuse, sigma_consistent_imap};
use cges::graph::{
    complete_pdag, d_separated, dag_from_bytes, dag_to_bytes, dag_to_cpdag, markov_equivalent,
    pdag_to_dag, Dag,
};
use cges::infer::factor::Factor;
use cges::infer::kernel::{self, reference};
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::smhd;
use cges::model::{bundle_from_bytes, bundle_to_bytes, Bundle, BundleMeta};
use cges::obs::{HistCursor, Histogram};
use cges::partition::{assign_edges, cluster_variables, partition_stats};
use cges::rng::Rng;
use cges::score::{
    bdeu_family_score, family_counts, family_counts_with_limit, pairwise_similarity, BdeuScorer,
    CountConfig, CountMode, Counter, CountsTable, FamilyCounts,
};
use cges::util::BitSet;

const TRIALS: u64 = 40;

fn random_cfg(rng: &mut Rng) -> NetGenConfig {
    let nodes = 6 + rng.gen_range(10);
    NetGenConfig {
        nodes,
        edges: nodes + rng.gen_range(nodes),
        max_parents: 2 + rng.gen_range(2),
        locality: 0,
        ..Default::default()
    }
}

#[test]
fn prop_cpdag_roundtrip_is_markov_equivalent() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed);
        let g = random_dag(&random_cfg(&mut rng), seed);
        let c = dag_to_cpdag(&g);
        let d = pdag_to_dag(&c).unwrap_or_else(|| panic!("seed {seed}: CPDAG not extendable"));
        assert!(markov_equivalent(&g, &d), "seed {seed}: round-trip left the class");
        // Completion is idempotent on CPDAGs.
        let c2 = complete_pdag(&c).unwrap();
        assert!(c2 == c, "seed {seed}: completion not idempotent");
    }
}

#[test]
fn prop_compelled_edges_shared_by_class() {
    // Every directed edge of the CPDAG must appear in every consistent
    // extension we can reach by re-extension.
    for seed in 0..TRIALS / 2 {
        let mut rng = Rng::new(seed ^ 0xAB);
        let g = random_dag(&random_cfg(&mut rng), seed);
        let c = dag_to_cpdag(&g);
        let d = pdag_to_dag(&c).unwrap();
        for v in 0..g.n() {
            for u in c.parents(v).iter() {
                assert!(d.has_edge(u, v), "seed {seed}: compelled {u}->{v} lost");
            }
        }
    }
}

#[test]
fn prop_fusion_is_imap_of_every_input() {
    // The fused DAG's independences must hold in every σ-transformed
    // input (checked by exhaustive d-separation on small graphs).
    for seed in 0..15u64 {
        let n = 6;
        let mk = |s: u64| {
            random_dag(
                &NetGenConfig { nodes: n, edges: 7, max_parents: 3, locality: 0, ..Default::default() },
                s,
            )
        };
        let g1 = mk(seed * 2 + 1);
        let g2 = mk(seed * 2 + 2);
        let (f, sigma) = fuse(&[&g1, &g2]);
        assert!(f.is_acyclic(), "seed {seed}");
        for g in [&g1, &g2] {
            let t = sigma_consistent_imap(g, &sigma);
            // Every edge of the transform is in the union.
            for (u, v) in t.edges() {
                assert!(f.has_edge(u, v), "seed {seed}: transform edge {u}->{v} missing");
            }
            // Fusion independences hold in the transform (I-map chain).
            for x in 0..n {
                for y in (x + 1)..n {
                    for z_bits in 0..(1u16 << n) {
                        let z = BitSet::from_iter(
                            n,
                            (0..n).filter(|&i| i != x && i != y && (z_bits >> i) & 1 == 1),
                        );
                        if d_separated(&f, x, y, &z) {
                            assert!(
                                d_separated(&t, x, y, &z),
                                "seed {seed}: fusion claims {x}⫫{y}|{z:?}, transform rejects"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_dag_wire_codec_roundtrips() {
    // The ring's wire transport ships models as bytes: for random DAGs
    // the codec must be the identity, and any strict prefix of a frame
    // must be rejected (a torn TCP read can never yield a wrong graph).
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xC0DE);
        let g = random_dag(&random_cfg(&mut rng), seed);
        let bytes = dag_to_bytes(&g);
        let back = dag_from_bytes(&bytes).unwrap_or_else(|e| panic!("seed {seed}: decode failed: {e}"));
        assert_eq!(back.n(), g.n(), "seed {seed}: node count changed");
        assert_eq!(back.edges(), g.edges(), "seed {seed}: edge set changed");

        let cuts = [0, 1, bytes.len() / 2, bytes.len() - 1];
        for cut in cuts {
            assert!(
                dag_from_bytes(&bytes[..cut]).is_err(),
                "seed {seed}: truncation to {cut}/{} bytes decoded",
                bytes.len()
            );
        }
    }
}

#[test]
fn prop_bif_roundtrip_preserves_network() {
    // write_bif -> read_bif must be the identity on netgen networks up
    // to print precision: names, cardinalities, edges and CPT cells all
    // survive, and the parser's row validation accepts every row the
    // writer emits.
    for seed in 0..TRIALS / 2 {
        let mut rng = Rng::new(seed ^ 0xB1F);
        let cfg = random_cfg(&mut rng);
        let bn = generate(&cfg, seed);
        let path = std::env::temp_dir().join(format!("cges_prop_bif_{seed}.bif"));
        write_bif(&bn, &path).unwrap_or_else(|e| panic!("seed {seed}: write failed: {e}"));
        let back = read_bif(&path).unwrap_or_else(|e| panic!("seed {seed}: read failed: {e}"));
        std::fs::remove_file(&path).ok();

        assert_eq!(back.names, bn.names, "seed {seed}: names changed");
        assert_eq!(back.cards, bn.cards, "seed {seed}: cardinalities changed");
        let mut e1 = bn.dag.edges();
        let mut e2 = back.dag.edges();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2, "seed {seed}: edge set changed");
        for v in 0..bn.n() {
            assert_eq!(back.cpts[v].parents, bn.cpts[v].parents, "seed {seed}: var {v} parents");
            for (a, b) in back.cpts[v].table.iter().zip(&bn.cpts[v].table) {
                assert!((a - b).abs() < 1e-8, "seed {seed}: var {v} cpt cell {a} vs {b}");
            }
        }
        back.validate().unwrap_or_else(|e| panic!("seed {seed}: invalid round-trip: {e}"));
    }
}

/// Random cardinalities (2..=4) for a universe of `n` variables.
fn random_cards(n: usize, rng: &mut Rng) -> Vec<usize> {
    (0..n).map(|_| 2 + rng.gen_range(3)).collect()
}

/// Random sorted scope over the universe (possibly empty when
/// `nonempty` is false).
fn random_scope(n: usize, nonempty: bool, rng: &mut Rng) -> Vec<usize> {
    loop {
        let v: Vec<usize> = (0..n).filter(|_| rng.bool(0.5)).collect();
        if !nonempty || !v.is_empty() {
            return v;
        }
    }
}

/// Random factor over `vars` with the universe's cards.
fn random_factor(vars: Vec<usize>, cards_of: &[usize], rng: &mut Rng) -> Factor {
    let cards: Vec<usize> = vars.iter().map(|&v| cards_of[v]).collect();
    let size: usize = cards.iter().product();
    let table: Vec<f64> = (0..size).map(|_| rng.f64()).collect();
    Factor { vars, cards, table }
}

/// Bit-level table equality with a 1e-12 pre-check for a readable
/// failure message (the blocked kernels promise bit-identity, which
/// subsumes the documented 1e-12 pin).
fn assert_tables_bit_equal(seed: u64, what: &str, got: &Factor, want: &Factor) {
    assert_eq!(got.vars, want.vars, "seed {seed}: {what} scope changed");
    assert_eq!(got.cards, want.cards, "seed {seed}: {what} cards changed");
    assert_eq!(got.table.len(), want.table.len(), "seed {seed}: {what} size changed");
    for (i, (a, b)) in got.table.iter().zip(&want.table).enumerate() {
        assert!((a - b).abs() < 1e-12, "seed {seed}: {what} cell {i}: {a} vs {b}");
        assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}: {what} cell {i} bits: {a} vs {b}");
    }
}

#[test]
fn prop_blocked_product_bitwise_matches_scalar_reference() {
    // The blocked product (and its in-place `_into` variant on a
    // reused buffer) must reproduce the scalar reference odometer
    // bit-for-bit on randomized scopes and cardinalities.
    let mut out = Factor::unit();
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xB10C);
        let n = 3 + rng.gen_range(5);
        let cards = random_cards(n, &mut rng);
        let a = random_factor(random_scope(n, false, &mut rng), &cards, &mut rng);
        let b = random_factor(random_scope(n, false, &mut rng), &cards, &mut rng);
        let want = reference::product(&a, &b);
        let got = Factor::product(&a, &b);
        assert_tables_bit_equal(seed, "product", &got, &want);
        Factor::product_into(&a, &b, &mut out);
        assert_tables_bit_equal(seed, "product_into", &out, &want);
        // In-place absorb of a subset-scope factor equals the product.
        let sub = random_factor(
            a.vars.iter().copied().filter(|_| rng.bool(0.6)).collect(),
            &cards,
            &mut rng,
        );
        let mut acc = a.clone();
        acc.absorb(&sub);
        let via = reference::product(&a, &sub);
        assert_tables_bit_equal(seed, "absorb", &acc, &via);
    }
}

#[test]
fn prop_blocked_marginalize_and_fused_match_scalar_reference() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xFADE);
        let n = 3 + rng.gen_range(5);
        let cards = random_cards(n, &mut rng);
        let f = random_factor(random_scope(n, true, &mut rng), &cards, &mut rng);
        let keep: Vec<usize> = f.vars.iter().copied().filter(|_| rng.bool(0.5)).collect();

        let want = reference::marginalize_to(&f, &keep);
        assert_tables_bit_equal(seed, "marginalize", &f.marginalize_to(&keep), &want);
        let mut into = Factor::unit();
        f.marginalize_into(&keep, &mut into);
        assert_tables_bit_equal(seed, "marginalize_into", &into, &want);
        let want_max = reference::max_marginalize_to(&f, &keep);
        assert_tables_bit_equal(seed, "max_marginalize", &f.max_marginalize_to(&keep), &want_max);

        // Fused absorb-and-marginalize vs materialize-then-fold, both
        // semirings, writing into a caller-owned buffer.
        let msg = random_factor(
            f.vars.iter().copied().filter(|_| rng.bool(0.5)).collect(),
            &cards,
            &mut rng,
        );
        let mut sm = Vec::new();
        let mut so = Vec::new();
        kernel::subset_strides_into(&f.vars, &f.cards, &msg.vars, &mut sm);
        kernel::subset_strides_into(&f.vars, &f.cards, &want.vars, &mut so);
        let prod = reference::product(&f, &msg);
        for max in [false, true] {
            let want_fused = if max {
                reference::max_marginalize_to(&prod, &keep)
            } else {
                reference::marginalize_to(&prod, &keep)
            };
            let mut out = vec![1.0; want_fused.table.len()];
            kernel::absorb_marginalize_into(
                &mut out, &f.table, &msg.table, &f.cards, &sm, &so, max,
            );
            for (i, (a, b)) in out.iter().zip(&want_fused.table).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed}: fused(max={max}) cell {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn prop_blocked_argmax_matches_scalar_reference() {
    // The strided argmax must agree with the walk-every-cell scalar
    // reference on value, winning digits and tie-breaking, under
    // random constraint sets.
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xA7A);
        let n = 3 + rng.gen_range(5);
        let cards = random_cards(n, &mut rng);
        let f = random_factor(random_scope(n, true, &mut rng), &cards, &mut rng);
        let fixed: Vec<Option<usize>> = (0..n)
            .map(|v| {
                if f.vars.contains(&v) && rng.bool(0.4) {
                    Some(rng.gen_range(cards[v]))
                } else {
                    None
                }
            })
            .collect();
        let (want_digits, want_val) = reference::argmax_consistent(&f, &fixed);
        let (got_digits, got_val) = f.argmax_consistent(&fixed);
        assert_eq!(got_val.to_bits(), want_val.to_bits(), "seed {seed}: argmax value");
        assert_eq!(got_digits, want_digits, "seed {seed}: argmax digits");
    }
}

#[test]
fn prop_evidence_mask_matches_indicator_product() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x3A5C);
        let n = 3 + rng.gen_range(4);
        let cards = random_cards(n, &mut rng);
        let f = random_factor(random_scope(n, true, &mut rng), &cards, &mut rng);
        let pos = rng.gen_range(f.vars.len());
        let v = f.vars[pos];
        let state = rng.gen_range(cards[v]);
        let want = reference::product(&f, &Factor::indicator(v, cards[v], state));
        let mut got = f.clone();
        kernel::mask_assign(&mut got.table, &got.cards, pos, state);
        assert_tables_bit_equal(seed, "mask_assign", &got, &want);
    }
}

/// Random bundle over a netgen network: random domain and CPTs, real
/// calibrated potentials on even seeds (the warm-start payload must
/// survive the codec bit-exactly), potential-less on odd ones.
fn random_bundle(seed: u64) -> Bundle {
    let mut rng = Rng::new(seed ^ 0xB0B5);
    let cfg = random_cfg(&mut rng);
    let bn = generate(&cfg, seed);
    let meta = BundleMeta {
        producer: format!("prop-{seed}"),
        rounds: seed as u32,
        score: -1.5 * seed as f64,
        ess: 1.0 + seed as f64 / 7.0,
    };
    if seed % 2 == 0 {
        Bundle::calibrated_within(bn, meta, u64::MAX)
    } else {
        Bundle::from_bn(bn, meta)
    }
}

#[test]
fn prop_bundle_codec_roundtrips_bit_exactly() {
    // encode -> decode must be the identity on every field that feeds
    // inference: names, cards, edges, CPT cells (bit-for-bit) and the
    // calibrated potentials (bit-for-bit — warm starts only stay
    // bit-identical to cold compiles because of this).
    for seed in 0..TRIALS / 2 {
        let bundle = random_bundle(seed);
        let bytes = bundle_to_bytes(&bundle);
        let back = bundle_from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("seed {seed}: decode failed: {e}"));

        assert_eq!(back.meta.producer, bundle.meta.producer, "seed {seed}");
        assert_eq!(back.meta.rounds, bundle.meta.rounds, "seed {seed}");
        assert_eq!(back.meta.score.to_bits(), bundle.meta.score.to_bits(), "seed {seed}");
        assert_eq!(back.meta.ess.to_bits(), bundle.meta.ess.to_bits(), "seed {seed}");
        assert_eq!(back.bn.names, bundle.bn.names, "seed {seed}: names changed");
        assert_eq!(back.bn.cards, bundle.bn.cards, "seed {seed}: cards changed");
        assert_eq!(back.bn.dag.edges(), bundle.bn.dag.edges(), "seed {seed}: edges changed");
        for v in 0..bundle.bn.n() {
            assert_eq!(back.bn.cpts[v].parents, bundle.bn.cpts[v].parents, "seed {seed} var {v}");
            for (i, (a, b)) in
                back.bn.cpts[v].table.iter().zip(&bundle.bn.cpts[v].table).enumerate()
            {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} var {v} cell {i}");
            }
        }
        assert_eq!(back.has_potentials(), bundle.has_potentials(), "seed {seed}");
        if let (Some(bp), Some(op)) = (&back.potentials, &bundle.potentials) {
            assert_eq!(bp.fingerprint, op.fingerprint, "seed {seed}: fingerprint changed");
            assert_eq!(bp.messages.len(), op.messages.len(), "seed {seed}");
            for (c, (m1, m2)) in bp.messages.iter().zip(&op.messages).enumerate() {
                assert_eq!(m1.len(), m2.len(), "seed {seed} clique {c}");
                for (a, b) in m1.iter().zip(m2) {
                    assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} clique {c}");
                }
            }
            for (a, b) in bp.logz.iter().zip(&op.logz) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}: logz changed");
            }
        }
    }
}

#[test]
fn prop_bundle_codec_rejects_truncation_and_foreign_versions() {
    // Any strict prefix must error (a torn read can never yield a
    // wrong model), and magic/version corruption must be refused with
    // a clear message — all without panicking.
    for seed in 0..TRIALS / 2 {
        let bytes = bundle_to_bytes(&random_bundle(seed));
        for cut in [0usize, 1, 4, 5, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                bundle_from_bytes(&bytes[..cut]).is_err(),
                "seed {seed}: truncation to {cut}/{} bytes decoded",
                bytes.len()
            );
        }

        let mut magic = bytes.clone();
        magic[0] ^= 0x20;
        let e = bundle_from_bytes(&magic).unwrap_err();
        assert!(format!("{e}").contains("magic"), "seed {seed}: {e}");

        let mut ver = bytes.clone();
        ver[4] = ver[4].wrapping_add(7);
        let e = bundle_from_bytes(&ver).unwrap_err();
        assert!(format!("{e}").contains("version"), "seed {seed}: {e}");

        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(bundle_from_bytes(&trailing).is_err(), "seed {seed}: trailing byte accepted");
    }
}

#[test]
fn prop_bundle_decoder_survives_random_corruption() {
    // Flip random bytes anywhere in the frame: the decoder must return
    // (Ok or Err), never panic, and anything it does accept must still
    // be a valid network (decode re-validates).
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xDEAD);
        let bytes = bundle_to_bytes(&random_bundle(seed % 6));
        let mut bad = bytes.clone();
        for _ in 0..3 {
            let pos = rng.gen_range(bad.len());
            bad[pos] ^= 1u8 << rng.gen_range(8);
        }
        if let Ok(b) = bundle_from_bytes(&bad) {
            b.bn.validate().unwrap_or_else(|e| {
                panic!("seed {seed}: decoder accepted an invalid network: {e}")
            });
        }
    }
}

#[test]
fn prop_partition_covers_disjointly_and_balances() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x51);
        let n = 8 + rng.gen_range(24);
        let k = 2 + rng.gen_range(3);
        // Random similarity matrix (symmetric).
        let mut s = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = rng.f64() * 20.0 - 10.0;
                s[i][j] = v;
                s[j][i] = v;
            }
        }
        let labels = cluster_variables(&s, k);
        assert_eq!(labels.len(), n);
        assert!(labels.iter().all(|&l| l < k), "seed {seed}");
        let masks = assign_edges(&labels, k);
        let stats = partition_stats(&masks, n);
        assert_eq!(stats.total, stats.expected, "seed {seed}: not a cover");
        for i in 0..n {
            for j in (i + 1)..n {
                assert_eq!(
                    masks.iter().filter(|m| m.allowed(i, j)).count(),
                    1,
                    "seed {seed}: pair ({i},{j}) not in exactly one subset"
                );
            }
        }
    }
}

#[test]
fn prop_smhd_is_a_metric_like_distance() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x77);
        let cfg = random_cfg(&mut rng);
        let a = random_dag(&cfg, seed);
        let b = random_dag(&cfg, seed + 1000);
        let c = random_dag(&cfg, seed + 2000);
        assert_eq!(smhd(&a, &a), 0);
        assert_eq!(smhd(&a, &b), smhd(&b, &a), "seed {seed}: asymmetric");
        // Triangle inequality holds for Hamming distances on edge sets.
        assert!(
            smhd(&a, &c) <= smhd(&a, &b) + smhd(&b, &c),
            "seed {seed}: triangle violated"
        );
    }
}

#[test]
fn prop_pairwise_similarity_matches_scorer_deltas() {
    for seed in 0..8u64 {
        let bn = generate(
            &NetGenConfig { nodes: 8, edges: 10, locality: 0, ..Default::default() },
            seed,
        );
        let data = Arc::new(forward_sample(&bn, 400, seed + 5));
        let pw = pairwise_similarity(&data, 10.0, 2);
        let sc = BdeuScorer::new(data, 10.0);
        for i in 0..8 {
            for j in 0..8 {
                if i == j {
                    continue;
                }
                let expect = sc.local(i, &[j]) - sc.local(i, &[]);
                assert!(
                    (pw.s[i][j] - expect).abs() < 1e-9,
                    "seed {seed}: S[{i}][{j}] mismatch"
                );
            }
        }
    }
}

#[test]
fn prop_ges_result_is_valid_cpdag_and_local_optimum_wrt_deletes() {
    for seed in 0..6u64 {
        let bn = generate(
            &NetGenConfig { nodes: 10, edges: 14, locality: 0, ..Default::default() },
            seed ^ 0xF,
        );
        let data = Arc::new(forward_sample(&bn, 1000, seed + 3));
        let sc = BdeuScorer::new(data, 10.0);
        let r = ges(&sc, &Dag::new(10), &GesConfig::default());
        // Result CPDAG must be a valid equivalence class: completion is
        // the identity on it.
        let completed = complete_pdag(&r.cpdag).expect("extendable");
        assert!(completed == r.cpdag, "seed {seed}: GES left a non-completed PDAG");
        // No single-edge deletion on the DAG view improves the score
        // (local optimality of BES at convergence).
        for (u, v) in r.dag.edges() {
            let mut pa: Vec<usize> = r.dag.parents(v).iter().collect();
            let before = sc.local(v, &pa);
            pa.retain(|&p| p != u);
            let after = sc.local(v, &pa);
            assert!(
                after <= before + 1e-9,
                "seed {seed}: deleting {u}->{v} improves score"
            );
        }
    }
}

/// Random raw dataset for the counting-core tests: cardinalities
/// mostly inside the bit-plane range (2..=5, so 1-/2-/4-bit packing
/// and the popcount path all fire), occasionally past it (9..=12:
/// packed but plane-less, exercising the decode fallback).
fn random_count_data(n: usize, rows: usize, rng: &mut Rng) -> Arc<Dataset> {
    let cards: Vec<u32> = (0..n)
        .map(|_| {
            if rng.gen_range(5) == 0 {
                9 + rng.gen_range(4) as u32
            } else {
                2 + rng.gen_range(4) as u32
            }
        })
        .collect();
    let cols: Vec<Vec<u8>> = cards
        .iter()
        .map(|&c| (0..rows).map(|_| rng.gen_range(c as usize) as u8).collect())
        .collect();
    Arc::new(Dataset::unnamed(cards, cols))
}

/// Random family: a child plus up to `max_parents` distinct parents
/// (excluding the child).
fn random_family(n: usize, max_parents: usize, rng: &mut Rng) -> (usize, Vec<usize>) {
    let child = rng.gen_range(n);
    let k = rng.gen_range(max_parents + 1);
    let mut parents = rng.sample_indices(n, (k + 1).min(n));
    parents.retain(|&p| p != child);
    parents.truncate(k);
    (child, parents)
}

/// The non-empty parent-configuration histograms in iteration order —
/// the comparable content of a [`FamilyCounts`] regardless of
/// representation (dense sweeps also visit empty configs, which carry
/// no counts, so drop them on both sides).
fn histograms(c: &FamilyCounts) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    c.for_each_config(|h| {
        if h.iter().any(|&x| x > 0) {
            out.push(h.to_vec());
        }
    });
    out
}

#[test]
fn prop_count_engines_match_scalar_reference_tables() {
    // Every engine path — popcount (≤2 parents, planed, small), blocked
    // row-tiled (forced via par_rows: 1), packed decode (plane-less or
    // 3-parent) — must reproduce the scalar reference count tables
    // exactly on randomized cardinalities, row counts and families.
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xC027);
        let n = 5 + rng.gen_range(4);
        let rows = 60 + rng.gen_range(400);
        let data = random_count_data(n, rows, &mut rng);
        let packed = Counter::new(data.clone(), CountConfig::default());
        let tiled = Counter::new(
            data.clone(),
            CountConfig { par_rows: 1, par_threads: 3, ..CountConfig::default() },
        );
        for _ in 0..12 {
            let (child, parents) = random_family(n, 3, &mut rng);
            let want = family_counts(&data, child, &parents);
            for (name, engine) in [("packed", &packed), ("tiled", &tiled)] {
                let got = engine.family_counts(child, &parents);
                assert_eq!(
                    got.r, want.r,
                    "seed {seed}: {name} r changed, child {child} parents {parents:?}"
                );
                assert_eq!(
                    histograms(&got),
                    histograms(&want),
                    "seed {seed}: {name} counts diverge, child {child} parents {parents:?}"
                );
            }
        }
    }
}

#[test]
fn prop_count_sparse_scores_match_dense_bitwise() {
    // Forcing the sorted-sparse representation (dense_limit = 1) must
    // leave every BDeu family score bit-identical to the dense sweep:
    // sparse iterates the same non-empty histograms in the same order,
    // so the float sequence is literally the same.
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x59A2);
        let n = 5 + rng.gen_range(4);
        let rows = 40 + rng.gen_range(300);
        let data = random_count_data(n, rows, &mut rng);
        let ess = 1.0 + (seed % 7) as f64;
        for _ in 0..10 {
            let (child, parents) = random_family(n, 3, &mut rng);
            let dense = family_counts(&data, child, &parents);
            let sparse = family_counts_with_limit(&data, child, &parents, 1);
            assert!(
                matches!(sparse.table, CountsTable::Sparse(_)),
                "seed {seed}: limit 1 did not force the sparse representation"
            );
            let q: f64 = parents.iter().map(|&p| data.card(p) as f64).product();
            assert_eq!(
                bdeu_family_score(&dense, q, ess).to_bits(),
                bdeu_family_score(&sparse, q, ess).to_bits(),
                "seed {seed}: sparse score bits diverge, child {child} parents {parents:?}"
            );
        }
    }
}

#[test]
fn prop_count_local_pair_matches_plain_locals_bitwise() {
    // The fused count-reuse path (one superset table + one derived
    // marginal) must equal two independent locals computed by the
    // scalar reference engine, bit for bit.
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xFA17);
        let n = 5 + rng.gen_range(4);
        let rows = 80 + rng.gen_range(300);
        let data = random_count_data(n, rows, &mut rng);
        let ess = 1.0 + (seed % 5) as f64;
        for _ in 0..6 {
            let (child, mut others) = random_family(n, 3, &mut rng);
            let Some(x) = others.pop() else { continue };
            let fused = BdeuScorer::new(data.clone(), ess);
            let plain = BdeuScorer::with_count_config(data.clone(), ess, CountConfig::reference());
            let (with_x, without_x) = fused.local_pair(child, &others, x);
            let mut sup = others.clone();
            sup.push(x);
            assert_eq!(
                with_x.to_bits(),
                plain.local(child, &sup).to_bits(),
                "seed {seed}: with_x bits diverge, child {child} others {others:?} x {x}"
            );
            assert_eq!(
                without_x.to_bits(),
                plain.local(child, &others).to_bits(),
                "seed {seed}: without_x bits diverge, child {child} others {others:?} x {x}"
            );
        }
    }
}

#[test]
fn prop_count_learners_byte_identical_across_count_modes() {
    // The whole point of bit-equal scores: GES, fGES and the ring
    // coordinator must make *identical decisions* under the packed
    // word-parallel engine and the scalar reference — same structure,
    // same score bits, same `score_dag` bits — on the same seeds.
    let modes = [CountMode::Reference, CountMode::Packed];
    for seed in 0..5u64 {
        let nodes = 12;
        let bn = generate(
            &NetGenConfig { nodes, edges: 16, locality: 0, ..Default::default() },
            seed ^ 0x6E5,
        );
        let data = Arc::new(forward_sample(&bn, 700, seed + 11));

        let mut ges_runs = Vec::new();
        let mut fges_runs = Vec::new();
        for &mode in &modes {
            let cfg = CountConfig { mode, ..CountConfig::default() };
            let sc = BdeuScorer::with_count_config(data.clone(), 10.0, cfg.clone());
            let r = ges(&sc, &Dag::new(nodes), &GesConfig::default());
            ges_runs.push((sc.score_dag(&r.dag), r));
            let sc = BdeuScorer::with_count_config(data.clone(), 10.0, cfg);
            let r = fges(&sc, &Dag::new(nodes), &FgesConfig::default());
            fges_runs.push((sc.score_dag(&r.dag), r));
        }
        for (name, runs) in [("GES", &ges_runs), ("fGES", &fges_runs)] {
            let (rescore_a, a) = &runs[0];
            let (rescore_b, b) = &runs[1];
            let mut ea = a.dag.edges();
            let mut eb = b.dag.edges();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "seed {seed}: {name} structures diverge across count modes");
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "seed {seed}: {name} score bits diverge across count modes"
            );
            assert_eq!(
                rescore_a.to_bits(),
                rescore_b.to_bits(),
                "seed {seed}: {name} score_dag bits diverge across count modes"
            );
        }

        let ring_runs: Vec<_> = modes
            .iter()
            .map(|&mode| {
                let cfg = RingConfig {
                    k: 2,
                    threads: 2,
                    mode: RingMode::Deterministic,
                    count_mode: mode,
                    ..RingConfig::default()
                };
                let r = cges(data.clone(), &cfg).unwrap_or_else(|e| {
                    panic!("seed {seed}: ring run failed under {mode:?}: {e}")
                });
                let sc = BdeuScorer::with_count_config(
                    data.clone(),
                    cfg.ess,
                    CountConfig::reference(),
                );
                (sc.score_dag(&r.dag), r)
            })
            .collect();
        let (rescore_a, a) = &ring_runs[0];
        let (rescore_b, b) = &ring_runs[1];
        let mut ea = a.dag.edges();
        let mut eb = b.dag.edges();
        ea.sort_unstable();
        eb.sort_unstable();
        assert_eq!(ea, eb, "seed {seed}: ring structures diverge across count modes");
        assert_eq!(
            a.score.to_bits(),
            b.score.to_bits(),
            "seed {seed}: ring score bits diverge across count modes"
        );
        assert_eq!(
            rescore_a.to_bits(),
            rescore_b.to_bits(),
            "seed {seed}: ring score_dag bits diverge across count modes"
        );
    }
}

#[test]
fn prop_histogram_quantiles_bracket_exact_order_statistics() {
    // The log-bucketed histogram never stores samples, only bucket
    // counts — the invariant that makes it usable anyway is that
    // `quantile_bounds(q)` returns exactly the bucket holding the
    // q-th order statistic of the recorded multiset, so every reported
    // percentile is off by at most one bucket width.
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x0b5);
        let n = 30 + rng.gen_range(470);
        // Spread samples across many octaves: a uniform u64 would land
        // almost everything in the top few buckets.
        let samples: Vec<u64> = (0..n)
            .map(|_| {
                let shift = rng.gen_range(64) as u32;
                rng.next_u64() >> shift
            })
            .collect();

        let h = Histogram::new();
        for &v in &samples {
            h.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();

        assert_eq!(h.count(), n as u64, "seed {seed}: count");
        assert_eq!(h.min(), sorted[0], "seed {seed}: min");
        assert_eq!(h.max(), *sorted.last().unwrap(), "seed {seed}: max");
        assert_eq!(
            h.sum(),
            samples.iter().fold(0u64, |a, &v| a.wrapping_add(v)),
            "seed {seed}: sum"
        );

        for &q in &[0.0, 0.01, 0.25, 0.50, 0.90, 0.99, 1.0] {
            // The same 1-based rank rule the histogram documents.
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = sorted[rank - 1];
            let (lo, hi) = h.quantile_bounds(q);
            assert!(
                lo <= exact && exact <= hi,
                "seed {seed}: q={q} exact {exact} outside bracket [{lo}, {hi}]"
            );
            // The bracket is exactly the one bucket containing the
            // order statistic — never wider.
            let idx = 64 - exact.leading_zeros() as usize;
            assert_eq!(
                (lo, hi),
                Histogram::bucket_bounds(idx),
                "seed {seed}: q={q} bracket is not the bucket of {exact}"
            );
            // The single-number summary stays inside the bracket and
            // on the far side of the exact statistic.
            let p = h.quantile(q);
            assert!(
                exact <= p && lo <= p && p <= hi,
                "seed {seed}: q={q} quantile {p} vs exact {exact} in [{lo}, {hi}]"
            );
        }

        // Distributed invariant: shipping the same multiset through
        // the delta/absorb wire path (one cursor, two incremental
        // deltas — exactly how the ring's obs wire batches per-round
        // shipments) reconstructs an equal histogram: same count, sum,
        // max and per-bucket occupancy, so merged quantile brackets
        // match the source's.
        let src = Histogram::new();
        let replayed = Histogram::new();
        let mut cursor = HistCursor::default();
        let half = samples.len() / 2;
        for &v in &samples[..half] {
            src.record(v);
        }
        replayed.absorb(&src.delta_since(&mut cursor));
        for &v in &samples[half..] {
            src.record(v);
        }
        replayed.absorb(&src.delta_since(&mut cursor));
        assert_eq!(replayed.count(), src.count(), "seed {seed}: replay count");
        assert_eq!(replayed.sum(), src.sum(), "seed {seed}: replay sum");
        assert_eq!(replayed.max(), src.max(), "seed {seed}: replay max");
        assert_eq!(
            replayed.nonzero_buckets(),
            src.nonzero_buckets(),
            "seed {seed}: replay bucket occupancy"
        );
        for &q in &[0.5, 0.99] {
            assert_eq!(
                replayed.quantile_bounds(q),
                src.quantile_bounds(q),
                "seed {seed}: replay q={q} bracket"
            );
        }
    }
}
