//! Steady-state allocation regression for the serving kernel path.
//!
//! The blocked-kernel rework promises that once a `Scratch` is warm,
//! `marginals` performs **zero table allocations** per query: every
//! potential, message, belief and work table lives in the scratch
//! arena, and the only fresh memory is the returned `Posterior`
//! (one vector of per-variable marginals, i.e. n + 1 allocations).
//! This test wraps the global allocator in a counter and pins that
//! bound, so any reintroduced per-query table allocation fails loudly.
//!
//! This lives in its own integration-test binary because a global
//! allocator is process-wide; the single test keeps the counter
//! readable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use cges::bn::{generate, NetGenConfig};
use cges::engine::CompiledModel;

/// System allocator with an allocation counter (dealloc is free).
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_marginals_allocate_only_the_posterior() {
    let cfg = NetGenConfig {
        nodes: 12,
        edges: 16,
        max_parents: 3,
        card_range: (2, 3),
        locality: 0,
        alpha: 0.8,
    };
    let bn = generate(&cfg, 17);
    let n = bn.n();
    let model = CompiledModel::compile(&bn).unwrap();
    let mut scratch = model.new_scratch();

    // Deterministic evidence cycle (grow, shrink, repeat) built before
    // measurement so the loop itself constructs nothing.
    let mut sequences: Vec<Vec<(usize, usize)>> = Vec::new();
    for seed in 0..4usize {
        for n_obs in [0usize, 1, 2, 3, 1, 0] {
            let ev: Vec<(usize, usize)> = (0..n_obs)
                .map(|i| {
                    let v = (seed * 3 + i * 5) % n;
                    (v, (seed + i) % bn.cards[v] as usize)
                })
                .collect();
            sequences.push(ev);
        }
    }

    // Warm-up: visit every evidence set once (marginals and joint
    // MAP, so the lazy max-product arena is sized too) and all
    // scratch buffers reach their final capacity, then measure.
    for ev in &sequences {
        model.marginals(&mut scratch, ev).unwrap();
        model.joint_map(&mut scratch, ev).unwrap();
    }
    const ROUNDS: usize = 20;
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..ROUNDS {
        for ev in &sequences {
            model.marginals(&mut scratch, ev).unwrap();
        }
    }
    let total = ALLOCS.load(Ordering::Relaxed) - before;
    let queries = ROUNDS * sequences.len();
    // Budget: the returned Posterior owns one marginal vector per
    // variable plus the outer vector; allow a little slack for the
    // allocator's own bookkeeping. Any per-query *table* allocation
    // (clique-sized, message-sized) would blow straight past this.
    let budget = queries * (n + 4);
    assert!(
        total <= budget,
        "steady-state marginals allocated {total} times over {queries} queries \
         (budget {budget}: the kernel path must not allocate tables)"
    );

    // Same bound for joint MAP: its max-product tables live in the
    // scratch arena, so a warm query allocates only the returned
    // assignment (plus the decode's Option buffer).
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..ROUNDS {
        for ev in &sequences {
            model.joint_map(&mut scratch, ev).unwrap();
        }
    }
    let total = ALLOCS.load(Ordering::Relaxed) - before;
    let budget = queries * 6;
    assert!(
        total <= budget,
        "steady-state joint_map allocated {total} times over {queries} queries \
         (budget {budget}: the max-product arena must not allocate tables)"
    );
}
