//! Steady-state allocation regression for the serving kernel path.
//!
//! The blocked-kernel rework promises that once a `Scratch` is warm,
//! `marginals` performs **zero table allocations** per query: every
//! potential, message, belief and work table lives in the scratch
//! arena, and the only fresh memory is the returned `Posterior`
//! (one vector of per-variable marginals, i.e. n + 1 allocations).
//! This test wraps the global allocator in a counter and pins that
//! bound, so any reintroduced per-query table allocation fails loudly.
//!
//! The same harness pins the scorer probe path: cold zero- and
//! one-parent family scoring must allocate a row-count-independent
//! handful per family (the count table and cache bookkeeping — never
//! anything per row), and warm probes must allocate nothing.
//!
//! This lives in its own integration-test binary because a global
//! allocator is process-wide; the tests serialize on [`LOCK`] so the
//! shared counter reads cleanly.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use cges::bn::{generate, NetGenConfig};
use cges::data::Dataset;
use cges::engine::CompiledModel;
use cges::score::BdeuScorer;

/// Serializes the tests in this binary: the allocation counter is
/// process-global, so concurrent tests would pollute each other.
static LOCK: Mutex<()> = Mutex::new(());

/// System allocator with an allocation counter (dealloc is free).
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_marginals_allocate_only_the_posterior() {
    let _guard = LOCK.lock().unwrap();
    let cfg = NetGenConfig {
        nodes: 12,
        edges: 16,
        max_parents: 3,
        card_range: (2, 3),
        locality: 0,
        alpha: 0.8,
    };
    let bn = generate(&cfg, 17);
    let n = bn.n();
    let model = CompiledModel::compile(&bn).unwrap();
    let mut scratch = model.new_scratch();

    // Deterministic evidence cycle (grow, shrink, repeat) built before
    // measurement so the loop itself constructs nothing.
    let mut sequences: Vec<Vec<(usize, usize)>> = Vec::new();
    for seed in 0..4usize {
        for n_obs in [0usize, 1, 2, 3, 1, 0] {
            let ev: Vec<(usize, usize)> = (0..n_obs)
                .map(|i| {
                    let v = (seed * 3 + i * 5) % n;
                    (v, (seed + i) % bn.cards[v] as usize)
                })
                .collect();
            sequences.push(ev);
        }
    }

    // Warm-up: visit every evidence set once (marginals and joint
    // MAP, so the lazy max-product arena is sized too) and all
    // scratch buffers reach their final capacity, then measure.
    for ev in &sequences {
        model.marginals(&mut scratch, ev).unwrap();
        model.joint_map(&mut scratch, ev).unwrap();
    }
    const ROUNDS: usize = 20;
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..ROUNDS {
        for ev in &sequences {
            model.marginals(&mut scratch, ev).unwrap();
        }
    }
    let total = ALLOCS.load(Ordering::Relaxed) - before;
    let queries = ROUNDS * sequences.len();
    // Budget: the returned Posterior owns one marginal vector per
    // variable plus the outer vector; allow a little slack for the
    // allocator's own bookkeeping. Any per-query *table* allocation
    // (clique-sized, message-sized) would blow straight past this.
    let budget = queries * (n + 4);
    assert!(
        total <= budget,
        "steady-state marginals allocated {total} times over {queries} queries \
         (budget {budget}: the kernel path must not allocate tables)"
    );

    // Same bound for joint MAP: its max-product tables live in the
    // scratch arena, so a warm query allocates only the returned
    // assignment (plus the decode's Option buffer).
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..ROUNDS {
        for ev in &sequences {
            model.joint_map(&mut scratch, ev).unwrap();
        }
    }
    let total = ALLOCS.load(Ordering::Relaxed) - before;
    let budget = queries * 6;
    assert!(
        total <= budget,
        "steady-state joint_map allocated {total} times over {queries} queries \
         (budget {budget}: the max-product arena must not allocate tables)"
    );
}

/// Deterministic synthetic dataset for the scorer probe test: `vars`
/// columns of the given cardinalities, `rows` rows, values from a
/// cheap mixing function so nothing degenerates to constant columns.
fn probe_data(cards: &[u32], rows: usize) -> Dataset {
    let cols: Vec<Vec<u8>> = cards
        .iter()
        .enumerate()
        .map(|(v, &card)| {
            (0..rows)
                .map(|t| {
                    let h = (t as u64)
                        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                        .wrapping_add(v as u64 * 0x517c_c1b7_2722_0a95);
                    ((h >> 33) % card as u64) as u8
                })
                .collect()
        })
        .collect();
    Dataset::unnamed(cards.to_vec(), cols)
}

#[test]
fn family_scoring_allocates_independent_of_row_count() {
    let _guard = LOCK.lock().unwrap();
    let cards: Vec<u32> = vec![2, 3, 2, 4, 2, 3, 2, 2, 3, 2];
    let n = cards.len();

    // The cold 0-/1-parent probe path must allocate a small, bounded
    // amount per family — the counts table (at most r·card cells), the
    // cache insert's bookkeeping, the parent-index vector — and never
    // anything proportional to the number of rows. Measuring the same
    // family sweep at 1k and 4k rows under one shared budget pins that:
    // a reintroduced per-row allocation passes neither size.
    let families = n + n * (n - 1); // all 0-parent + all 1-parent
    let cold_budget = families * 16 + 64; // + slack for cache shard tables
    for rows in [1000usize, 4000] {
        let data = std::sync::Arc::new(probe_data(&cards, rows));
        // Construction packs the dataset into bit-planes; that is
        // allowed to allocate, so it happens outside the window.
        let sc = BdeuScorer::new(data, 8.0);

        let before = ALLOCS.load(Ordering::Relaxed);
        for child in 0..n {
            sc.local(child, &[]);
            for p in 0..n {
                if p != child {
                    sc.local(child, &[p]);
                }
            }
        }
        let cold = ALLOCS.load(Ordering::Relaxed) - before;
        assert!(
            cold <= cold_budget,
            "cold scoring of {families} families at {rows} rows allocated {cold} times \
             (budget {cold_budget}: the popcount counting path must not allocate per row)"
        );

        // Warm probes are pure cache hits through stack-inline keys:
        // the whole sweep must not touch the heap at all.
        let before = ALLOCS.load(Ordering::Relaxed);
        for child in 0..n {
            sc.local(child, &[]);
            for p in 0..n {
                if p != child {
                    sc.local(child, &[p]);
                }
            }
        }
        let warm = ALLOCS.load(Ordering::Relaxed) - before;
        assert!(
            warm <= 8,
            "warm probes of {families} cached families allocated {warm} times \
             (the inline-key cache hit path must be allocation-free)"
        );
    }
}
