//! Fleet runtime integration tests: the event-loop server against
//! real sockets.
//!
//! The contracts under test: (1) pipelined framed queries through the
//! fleet are byte-identical to the thread-pool server on the same
//! bundle, and responses come back in request order regardless of
//! worker completion order; (2) the `{"type": "shutdown"}` sentinel
//! drains already-pipelined frames cleanly in BOTH runtimes — every
//! frame written before the close gets its response; (3) a live
//! `switch` under query load drops zero in-flight queries: every
//! response is byte-identical to one of the two hosted models, and a
//! query issued after the swap ack answers from the new model; (4) an
//! oversized frame is answered with one typed error (thread-pool cap
//! wording) instead of a torn connection; (5) a mid-frame client
//! disconnect during swap churn is contained — counted as a failed
//! connection, leaking no registry entry and leaving the fleet
//! serviceable.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};

use cges::bn::{generate, NetGenConfig};
use cges::engine::{FleetConfig, FleetServer, ServeConfig, Server};
use cges::infer::json::Json;
use cges::infer::EngineConfig;
use cges::model::{bundle_fingerprint, fingerprint_hex, Bundle, BundleMeta};

fn small_cfg(nodes: usize, edges: usize) -> NetGenConfig {
    NetGenConfig { nodes, edges, max_parents: 3, card_range: (2, 3), locality: 0, alpha: 0.8 }
}

/// A calibrated bundle over a generated network (the `producer` tag
/// alone already yields a distinct fingerprint, but distinct seeds
/// give genuinely different CPTs, so served bytes differ too).
fn bundle(seed: u64, tag: &str) -> Bundle {
    let bn = generate(&small_cfg(8, 11), seed);
    let meta = BundleMeta { producer: tag.into(), rounds: 0, score: 0.0, ess: 1.0 };
    Bundle::calibrated_within(bn, meta, u64::MAX)
}

fn send_frame(writer: &mut impl Write, payload: &str) {
    let bytes = payload.as_bytes();
    writer.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
    writer.write_all(bytes).unwrap();
    writer.flush().unwrap();
}

fn recv_frame(reader: &mut impl Read) -> String {
    let mut len_bytes = [0u8; 4];
    reader.read_exact(&mut len_bytes).unwrap();
    let len = u32::from_le_bytes(len_bytes) as usize;
    let mut payload = vec![0u8; len];
    reader.read_exact(&mut payload).unwrap();
    String::from_utf8(payload).unwrap()
}

/// Thread-pool reference answers for a request script on one bundle.
fn reference_answers(b: &Bundle, requests: &[String]) -> Vec<String> {
    let pool = Server::from_bundle(b, &EngineConfig::default(), ServeConfig::default()).unwrap();
    let mut scratch = pool.new_scratch();
    requests.iter().map(|q| pool.handle(&mut scratch, q)).collect()
}

#[test]
fn pipelined_fleet_queries_match_threadpool_bytes_in_order() {
    let b = bundle(5, "pin");
    let requests: Vec<String> = (0..24)
        .map(|q| match q % 4 {
            // The batch (slowest) leads, so with 4 workers later light
            // queries finish first — the reorder map must still emit
            // wire order.
            0 => format!(
                r#"{{"id": {q}, "type": "batch", "queries": [{{"id": 0}}, {{"id": 1, "type": "joint_map"}}, {{"id": 2, "type": "map"}}]}}"#
            ),
            1 => format!(r#"{{"id": {q}, "type": "marginal", "evidence": {{"X0": 0}}}}"#),
            2 => format!(r#"{{"id": {q}, "type": "map"}}"#),
            _ => format!(r#"{{"id": {q}, "type": "joint_map", "evidence": {{"X1": 0}}}}"#),
        })
        .collect();
    let expected = reference_answers(&b, &requests);

    let fleet = FleetServer::new(
        EngineConfig::default(),
        FleetConfig { workers: 4, ..Default::default() },
    );
    fleet.load_bundle(&b).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let fleet = &fleet;
        s.spawn(move || fleet.serve(&listener, Some(1)).unwrap());

        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        // The whole script in one burst before reading anything.
        for req in &requests {
            send_frame(&mut writer, req);
        }
        for (i, want) in expected.iter().enumerate() {
            let got = recv_frame(&mut reader);
            assert_eq!(&got, want, "slot {i} diverged from the thread-pool answer");
        }
    });

    let reg = fleet.registry();
    assert_eq!(reg.gauge_value("fleet.conns_open"), Some(0.0));
    assert_eq!(reg.counter_value("fleet.conns_failed"), Some(0));
    assert!(reg.counter_value("serve.requests").unwrap() >= requests.len() as u64);
}

#[test]
fn shutdown_drains_pipelined_frames_in_both_runtimes() {
    let b = bundle(7, "drain");
    let script = [
        r#"{"id": 1}"#,
        r#"{"id": 2, "type": "map"}"#,
        r#"{"id": 3, "type": "shutdown"}"#,
        r#"{"id": 4, "type": "joint_map"}"#,
        r#"{"id": 5}"#,
    ];

    let drive = |addr: std::net::SocketAddr| {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        for req in &script {
            send_frame(&mut writer, req);
        }
        let responses: Vec<Json> =
            (0..script.len()).map(|_| Json::parse(&recv_frame(&mut reader)).unwrap()).collect();
        for (i, v) in responses.iter().enumerate() {
            assert_eq!(v.get("id").and_then(Json::as_usize), Some(i + 1), "slot {i}");
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "slot {i}: {v:?}");
        }
        assert_eq!(responses[2].get("shutdown").and_then(Json::as_bool), Some(true));
        let mut probe = [0u8; 1];
        let n = reader.read(&mut probe).unwrap_or(0);
        assert_eq!(n, 0, "connection should close after the drain");
    };

    // Event-loop runtime.
    let fleet = FleetServer::new(
        EngineConfig::default(),
        FleetConfig { workers: 2, ..Default::default() },
    );
    fleet.load_bundle(&b).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        let fleet = &fleet;
        let handle = s.spawn(move || fleet.serve(&listener, None).unwrap());
        drive(addr);
        handle.join().unwrap();
    });
    assert!(fleet.is_shutting_down());
    assert_eq!(fleet.registry().counter_value("fleet.conns_failed"), Some(0));

    // Thread-pool runtime, identical script and expectations.
    let pool = Server::from_bundle(&b, &EngineConfig::default(), ServeConfig::default()).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        let pool = &pool;
        let handle = s.spawn(move || pool.serve_tcp(&listener, None).unwrap());
        drive(addr);
        handle.join().unwrap();
    });
    assert!(pool.is_shutting_down());
    assert_eq!(pool.registry().counter_value("serve.conns_failed"), Some(0));
}

#[test]
fn hot_swap_under_load_drops_zero_queries() {
    const BURSTS: usize = 20;
    const PER_BURST: usize = 10;

    let (ba, bb) = (bundle(11, "model-a"), bundle(12, "model-b"));
    let (fa, fb) = (bundle_fingerprint(&ba), bundle_fingerprint(&bb));
    // One fixed query both models answer; the reference bytes differ
    // (different CPTs), which is what lets each response be attributed.
    let query = r#"{"id": 7, "type": "marginal", "evidence": {"X0": 0}}"#.to_string();
    let ref_a = reference_answers(&ba, std::slice::from_ref(&query)).remove(0);
    let ref_b = reference_answers(&bb, std::slice::from_ref(&query)).remove(0);
    assert_ne!(ref_a, ref_b, "the two models must serve distinguishable bytes");

    let fleet = FleetServer::new(
        EngineConfig::default(),
        FleetConfig { workers: 2, ..Default::default() },
    );
    fleet.load_bundle(&ba).unwrap();
    fleet.load_bundle(&bb).unwrap();
    assert_eq!(fleet.active_fingerprint(), Some(fa), "first load is active");
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    let (from_a, from_b) = std::thread::scope(|s| {
        let fleet = &fleet;
        let server = s.spawn(move || fleet.serve(&listener, None).unwrap());

        // Query load: bursts of pipelined frames, read back between
        // bursts, spanning the swap.
        let query = &query;
        let (ref_a, ref_b) = (&ref_a, &ref_b);
        let load = s.spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            let (mut from_a, mut from_b) = (0usize, 0usize);
            for _ in 0..BURSTS {
                for _ in 0..PER_BURST {
                    send_frame(&mut writer, query);
                }
                for _ in 0..PER_BURST {
                    let got = recv_frame(&mut reader);
                    // Zero dropped, zero errored: every single response
                    // is a complete answer from one of the two models.
                    if &got == ref_a {
                        from_a += 1;
                    } else if &got == ref_b {
                        from_b += 1;
                    } else {
                        panic!("response matches neither model: {got}");
                    }
                }
            }
            (from_a, from_b)
        });

        // Control plane: swap to B mid-load, then check, then shut
        // down once the load finishes.
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let fb_hex = fingerprint_hex(fb);
        send_frame(&mut writer, &format!(r#"{{"type": "switch", "model": "{fb_hex}"}}"#));
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "switch failed: {v:?}");
        assert_eq!(v.get("active").and_then(Json::as_str), Some(fb_hex.as_str()));

        // A query issued after the swap ack must answer from B.
        send_frame(&mut writer, &query.clone());
        assert_eq!(recv_frame(&mut reader), *ref_b, "post-swap query not from the new model");

        // The models list reflects the swap.
        send_frame(&mut writer, r#"{"type": "models"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("active").and_then(Json::as_str), Some(fb_hex.as_str()));
        assert_eq!(v.get("models").and_then(Json::as_array).unwrap().len(), 2);

        let counts = load.join().unwrap();
        send_frame(&mut writer, r#"{"type": "shutdown"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("shutdown").and_then(Json::as_bool), Some(true));
        server.join().unwrap();
        counts
    });

    // Every one of the BURSTS * PER_BURST in-flight queries was
    // answered by exactly one model, and the swap genuinely happened
    // under load (the post-swap B answer is asserted above; whether
    // phase 1 caught both sides depends on timing, so only the total
    // is pinned).
    assert_eq!(from_a + from_b, BURSTS * PER_BURST);
    let reg = fleet.registry();
    assert_eq!(reg.counter_value("fleet.swaps"), Some(1));
    assert_eq!(reg.counter_value("fleet.conns_failed"), Some(0));
    assert_eq!(reg.gauge_value("fleet.conns_open"), Some(0.0));
    // Both per-model request counters saw traffic.
    assert!(reg.counter_value(&format!("serve.{}.requests", fingerprint_hex(fa))).unwrap() >= 1);
    assert!(reg.counter_value(&format!("serve.{}.requests", fingerprint_hex(fb))).unwrap() >= 1);
}

#[test]
fn oversized_frame_answers_typed_error_then_closes() {
    let fleet = FleetServer::new(
        EngineConfig::default(),
        FleetConfig { max_frame_bytes: 256, ..Default::default() },
    );
    fleet.load_bundle(&bundle(3, "cap")).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let fleet = &fleet;
        s.spawn(move || fleet.serve(&listener, Some(1)).unwrap());

        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writer.write_all(&1024u32.to_le_bytes()).unwrap();
        writer.flush().unwrap();
        // The thread pool tears the connection here; the event loop
        // answers a typed error with the shared cap wording, then
        // closes cleanly.
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            v.get("error").and_then(Json::as_str),
            Some("incoming frame of 1024 bytes exceeds cap 256"),
            "cap wording must match util::ensure_frame_len"
        );
        let mut probe = [0u8; 1];
        let n = reader.read(&mut probe).unwrap_or(0);
        assert_eq!(n, 0, "connection closes after the rejection");
    });

    let reg = fleet.registry();
    assert_eq!(reg.counter_value("fleet.frames_rejected"), Some(1));
    assert_eq!(reg.gauge_value("fleet.conns_open"), Some(0.0));
}

#[test]
fn chaos_mid_frame_disconnect_during_swap_churn_leaks_nothing() {
    let (ba, bb) = (bundle(21, "chaos-a"), bundle(22, "chaos-b"));
    let (fa, fb) = (bundle_fingerprint(&ba), bundle_fingerprint(&bb));
    let fleet = FleetServer::new(
        EngineConfig::default(),
        FleetConfig { workers: 2, ..Default::default() },
    );
    fleet.load_bundle(&ba).unwrap();
    fleet.load_bundle(&bb).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let fleet = &fleet;
        let server = s.spawn(move || fleet.serve(&listener, None).unwrap());

        // Control connection churning the active model.
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        for fp in [fb, fa, fb] {
            let hex = fingerprint_hex(fp);
            send_frame(&mut writer, &format!(r#"{{"type": "switch", "model": "{hex}"}}"#));
            let v = Json::parse(&recv_frame(&mut reader)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{v:?}");

            // Chaos between swaps: a client dies mid-frame (prefix
            // promising 64 bytes, 4 delivered, then a hard drop).
            let mut victim = TcpStream::connect(addr).unwrap();
            victim.write_all(&64u32.to_le_bytes()).unwrap();
            victim.write_all(b"{\"id").unwrap();
            victim.flush().unwrap();
            drop(victim);
        }

        // The fleet still serves: a live query answers on the active
        // model, and the registry kept both entries.
        send_frame(&mut writer, r#"{"id": 1, "type": "marginal"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        send_frame(&mut writer, r#"{"type": "models"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("models").and_then(Json::as_array).unwrap().len(), 2);

        // The inactive model still unloads cleanly (no scratch or
        // registry entry was leaked to the dead connections).
        let fa_hex = fingerprint_hex(fa);
        send_frame(&mut writer, &format!(r#"{{"type": "unload", "model": "{fa_hex}"}}"#));
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{v:?}");

        send_frame(&mut writer, r#"{"type": "shutdown"}"#);
        let v = Json::parse(&recv_frame(&mut reader)).unwrap();
        assert_eq!(v.get("shutdown").and_then(Json::as_bool), Some(true));
        server.join().unwrap();
    });

    let reg = fleet.registry();
    // Each of the three victims died mid-frame: counted failed, none
    // left open, and the model registry is exactly the surviving entry.
    assert_eq!(reg.counter_value("fleet.conns_failed"), Some(3));
    assert_eq!(reg.gauge_value("fleet.conns_open"), Some(0.0));
    assert_eq!(fleet.models().len(), 1);
    assert_eq!(fleet.active_fingerprint(), Some(fb));
}
