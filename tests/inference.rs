//! Inference exactness and serving-path integration tests.
//!
//! Ground truth is brute-force joint enumeration (feasible to ~12
//! variables): the join tree and variable elimination must match it to
//! 1e-9, likelihood weighting must converge on the 2-node network, and
//! the serve path must answer the same numbers over both the line
//! protocol and framed TCP.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};

use cges::bn::{fit, forward_sample, generate, Cpt, DiscreteBn, NetGenConfig};
use cges::graph::Dag;
use cges::infer::json::Json;
use cges::infer::{likelihood_weighting, ve_marginal, EngineConfig, JoinTree, QueryServer};

/// The 2-node network `a -> b` used across the unit tests, rebuilt
/// here because integration tests cannot see `#[cfg(test)]` helpers.
fn tiny_bn() -> DiscreteBn {
    DiscreteBn {
        dag: Dag::from_edges(2, &[(0, 1)]),
        names: vec!["a".into(), "b".into()],
        cards: vec![2, 2],
        cpts: vec![
            Cpt { parents: vec![], table: vec![0.7, 0.3], r: 2 },
            Cpt { parents: vec![0], table: vec![0.9, 0.1, 0.2, 0.8], r: 2 },
        ],
    }
}

fn small_cfg(nodes: usize, edges: usize) -> NetGenConfig {
    NetGenConfig { nodes, edges, max_parents: 3, card_range: (2, 3), locality: 0, alpha: 0.8 }
}

/// Brute-force posterior: enumerate every complete assignment, filter
/// on evidence, accumulate marginals. Returns (marginals, P(evidence)).
fn enumerate_posterior(bn: &DiscreteBn, evidence: &[(usize, usize)]) -> (Vec<Vec<f64>>, f64) {
    let n = bn.n();
    let cards: Vec<usize> = bn.cards.iter().map(|&c| c as usize).collect();
    let mut marginals: Vec<Vec<f64>> = cards.iter().map(|&c| vec![0.0; c]).collect();
    let mut p_evidence = 0.0f64;
    let mut states = vec![0u8; n];
    let mut done = false;
    while !done {
        let mut p = 1.0f64;
        for v in 0..n {
            let cfg = bn.parent_config(v, &states, &bn.cards);
            p *= bn.cpts[v].row(cfg)[states[v] as usize];
        }
        if evidence.iter().all(|&(v, s)| states[v] as usize == s) {
            p_evidence += p;
            for (hist, &s) in marginals.iter_mut().zip(&states) {
                hist[s as usize] += p;
            }
        }
        // Mixed-radix increment.
        done = true;
        for (st, &c) in states.iter_mut().zip(&cards) {
            *st += 1;
            if (*st as usize) < c {
                done = false;
                break;
            }
            *st = 0;
        }
    }
    assert!(p_evidence > 0.0, "test evidence must have positive probability");
    for hist in &mut marginals {
        hist.iter_mut().for_each(|x| *x /= p_evidence);
    }
    (marginals, p_evidence)
}

fn evidence_for(seed: u64, bn: &DiscreteBn, n_obs: usize) -> Vec<(usize, usize)> {
    // Deterministic distinct evidence vars with in-range states.
    let n = bn.n();
    (0..n_obs)
        .map(|i| {
            let v = ((seed as usize) * 3 + i * 5) % n;
            let s = ((seed as usize) + i) % bn.cards[v] as usize;
            (v, s)
        })
        .filter({
            // Drop duplicate vars (conflicts would zero the evidence).
            let mut seen: Vec<usize> = Vec::new();
            move |&(v, _)| {
                if seen.contains(&v) {
                    false
                } else {
                    seen.push(v);
                    true
                }
            }
        })
        .collect()
}

#[test]
fn jointree_matches_enumeration() {
    for seed in 0..6u64 {
        let bn = generate(&small_cfg(9, 12), seed);
        let jt = JoinTree::build(&bn).unwrap();
        for n_obs in 0..3usize {
            let evidence = evidence_for(seed, &bn, n_obs);
            let (want, pe) = enumerate_posterior(&bn, &evidence);
            let post = jt.posterior(&evidence).unwrap();
            assert!(
                (post.log_evidence - pe.ln()).abs() < 1e-9,
                "seed {seed} obs {n_obs}: log evidence {} vs {}",
                post.log_evidence,
                pe.ln()
            );
            for v in 0..bn.n() {
                for (a, b) in post.marginal(v).iter().zip(&want[v]) {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "seed {seed} obs {n_obs} var {v}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn ve_matches_enumeration_and_jointree() {
    for seed in 0..4u64 {
        let bn = generate(&small_cfg(10, 14), seed ^ 0x7E);
        let evidence = evidence_for(seed, &bn, 2);
        let (want, _) = enumerate_posterior(&bn, &evidence);
        let jt = JoinTree::build(&bn).unwrap();
        let post = jt.posterior(&evidence).unwrap();
        for v in 0..bn.n() {
            let ve = ve_marginal(&bn, v, &evidence).unwrap();
            for ((a, b), c) in ve.iter().zip(&want[v]).zip(post.marginal(v)) {
                assert!((a - b).abs() < 1e-9, "seed {seed} var {v}: ve {a} vs brute {b}");
                assert!((a - c).abs() < 1e-9, "seed {seed} var {v}: ve {a} vs jointree {c}");
            }
        }
    }
}

#[test]
fn likelihood_weighting_converges_on_tiny_bn() {
    let bn = tiny_bn();
    let evidence = vec![(1usize, 1usize)];
    let (want, pe) = enumerate_posterior(&bn, &evidence);
    let post = likelihood_weighting(&bn, &evidence, 400_000, 20260730).unwrap();
    for v in 0..bn.n() {
        for (a, b) in post.marginal(v).iter().zip(&want[v]) {
            assert!((a - b).abs() < 0.01, "var {v}: lw {a} vs exact {b}");
        }
    }
    assert!((post.log_evidence - pe.ln()).abs() < 0.05);
}

#[test]
fn fit_then_query_closes_the_loop() {
    // Learn-free end-to-end: sample from a known net, fit CPTs onto its
    // structure, and check queries against the *fitted* network agree
    // between engines — plus the fitted marginal lands near the truth.
    let truth = generate(&small_cfg(8, 10), 99);
    let data = forward_sample(&truth, 20_000, 4);
    let fitted = fit(&truth.dag, &data, 1.0).unwrap();
    fitted.validate().unwrap();

    let evidence = vec![(0usize, 0usize)];
    let (want_fitted, _) = enumerate_posterior(&fitted, &evidence);
    let jt = JoinTree::build(&fitted).unwrap();
    let post = jt.posterior(&evidence).unwrap();
    let (want_truth, _) = enumerate_posterior(&truth, &evidence);
    for v in 0..fitted.n() {
        for (a, b) in post.marginal(v).iter().zip(&want_fitted[v]) {
            assert!((a - b).abs() < 1e-9, "var {v}: {a} vs {b}");
        }
        // Fitted posterior tracks the generating posterior.
        for (a, b) in post.marginal(v).iter().zip(&want_truth[v]) {
            assert!((a - b).abs() < 0.05, "var {v}: fitted {a} far from truth {b}");
        }
    }
}

#[test]
fn serve_line_protocol_matches_enumeration() {
    let bn = generate(&small_cfg(7, 9), 5);
    let mut server = QueryServer::new(&bn, &EngineConfig::default()).unwrap();
    assert_eq!(server.engine_name(), "jointree");

    let evidence = vec![(1usize, 0usize)];
    let (want, pe) = enumerate_posterior(&bn, &evidence);
    let req = format!(
        r#"{{"id": 1, "type": "marginal", "targets": ["{}"], "evidence": {{"{}": 0}}}}"#,
        bn.names[0], bn.names[1]
    );
    let v = Json::parse(&server.handle(&req)).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(1));
    let le = v.get("log_evidence").and_then(Json::as_f64).unwrap();
    assert!((le - pe.ln()).abs() < 1e-9);
    let dist = v
        .get("marginals")
        .and_then(|m| m.get(&bn.names[0]))
        .and_then(Json::as_array)
        .unwrap();
    for (cell, b) in dist.iter().zip(&want[0]) {
        assert!((cell.as_f64().unwrap() - b).abs() < 1e-9);
    }

    // MAP answers are the per-variable posterior modes.
    let req = format!(r#"{{"id": 2, "type": "map", "evidence": {{"{}": 0}}}}"#, bn.names[1]);
    let v = Json::parse(&server.handle(&req)).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let map = v.get("map").unwrap();
    for (vi, name) in bn.names.iter().enumerate() {
        let got = map.get(name).and_then(Json::as_usize).unwrap();
        let mut best = 0usize;
        for (s, &p) in want[vi].iter().enumerate() {
            if p > want[vi][best] {
                best = s;
            }
        }
        assert_eq!(got, best, "var {name}");
    }
}

fn send_frame(writer: &mut impl Write, payload: &str) {
    let bytes = payload.as_bytes();
    writer.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
    writer.write_all(bytes).unwrap();
    writer.flush().unwrap();
}

fn recv_frame(reader: &mut impl Read) -> String {
    let mut len_bytes = [0u8; 4];
    reader.read_exact(&mut len_bytes).unwrap();
    let len = u32::from_le_bytes(len_bytes) as usize;
    let mut payload = vec![0u8; len];
    reader.read_exact(&mut payload).unwrap();
    String::from_utf8(payload).unwrap()
}

#[test]
fn serve_tcp_framed_roundtrip() {
    let bn = generate(&small_cfg(6, 8), 13);
    let mut server = QueryServer::new(&bn, &EngineConfig::default()).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    let handle = std::thread::spawn(move || {
        server.serve_tcp(&listener, Some(1)).unwrap();
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);

    // Two requests on one connection: a good one and an error one.
    send_frame(&mut writer, r#"{"id": 10, "type": "marginal"}"#);
    let v = Json::parse(&recv_frame(&mut reader)).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(10));
    let margs = v.get("marginals").and_then(Json::as_object).unwrap();
    assert_eq!(margs.len(), bn.n());
    let (want, _) = enumerate_posterior(&bn, &[]);
    for (name, dist) in margs {
        let vi = bn.names.iter().position(|n| n == name).unwrap();
        for (cell, b) in dist.as_array().unwrap().iter().zip(&want[vi]) {
            assert!((cell.as_f64().unwrap() - b).abs() < 1e-9);
        }
    }

    send_frame(&mut writer, r#"{"id": 11, "targets": ["not_a_var"]}"#);
    let v = Json::parse(&recv_frame(&mut reader)).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(11));

    drop(writer);
    drop(reader);
    handle.join().unwrap();
}
