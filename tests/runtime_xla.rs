//! Cross-layer integration: the AOT XLA artifact (L1 Pallas kernel +
//! L2 JAX model, lowered to HLO text) executed through PJRT must agree
//! with the independent Rust implementation across shapes, paddings
//! and arities.
//!
//! Quarantined behind the `xla` cargo feature: the default offline
//! build has neither the `xla` crate nor PJRT runtime artifacts, so
//! this whole test crate compiles to nothing there. To run it in an
//! artifact-equipped environment, first add the `xla` crate to
//! `[dependencies]` in Cargo.toml (it is deliberately not listed —
//! the offline registry cannot resolve it), then
//! `cargo test --features xla`. Even with the feature on, each test
//! skips gracefully — with a note — when `make artifacts` has not
//! produced `artifacts/manifest.txt`.
#![cfg(feature = "xla")]

use std::path::PathBuf;
use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::coordinator::{cges, PartitionSource, RingConfig};
use cges::runtime::SimilarityRuntime;
use cges::score::pairwise_similarity;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn check_match(n: usize, m: usize, cards: (u32, u32), seed: u64, rt: &SimilarityRuntime) {
    let bn = generate(
        &NetGenConfig { nodes: n, edges: n * 4 / 3, card_range: cards, ..Default::default() },
        seed,
    );
    let data = forward_sample(&bn, m, seed + 1);
    assert!(rt.supports(&data), "no config for n={n} m={m}");
    let xla = rt.pairwise(&data, 10.0).expect("artifact run");
    let rust = pairwise_similarity(&data, 10.0, 4);
    let mut max_err: f64 = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let denom = rust.s[i][j].abs().max(1.0);
            max_err = max_err.max((xla.s[i][j] - rust.s[i][j]).abs() / denom);
        }
    }
    // f32 lgamma error accumulates over r² terms with counts up to m;
    // 0.5% relative is the expected noise floor for these shapes.
    assert!(max_err < 5e-3, "n={n} m={m}: relative error {max_err}");
}

#[test]
fn artifact_agrees_across_shapes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = SimilarityRuntime::load(&dir).expect("load runtime");
    // Different configs get selected by size: tiny, small.
    check_match(20, 200, (2, 4), 1, &rt);
    check_match(60, 900, (2, 4), 2, &rt);
    // Higher arity exercises the r_max=8 configs.
    check_match(40, 800, (2, 8), 3, &rt);
}

#[test]
fn artifact_execution_is_deterministic() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = SimilarityRuntime::load(&dir).expect("load runtime");
    let bn = generate(&NetGenConfig { nodes: 16, edges: 20, ..Default::default() }, 9);
    let data = forward_sample(&bn, 300, 2);
    let a = rt.pairwise(&data, 10.0).unwrap();
    let b = rt.pairwise(&data, 10.0).unwrap();
    for i in 0..16 {
        assert_eq!(a.s[i], b.s[i], "row {i} differs between runs");
        assert_eq!(a.empty[i], b.empty[i]);
    }
}

#[test]
fn ring_with_artifact_partition_matches_fallback_quality() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let bn = generate(&NetGenConfig { nodes: 24, edges: 32, ..Default::default() }, 17);
    let data = Arc::new(forward_sample(&bn, 1000, 4));
    let with_xla = cges(
        data.clone(),
        &RingConfig {
            k: 2,
            partition_source: PartitionSource::Artifacts(dir),
            ..Default::default()
        },
    )
    .unwrap();
    let with_rust = cges(
        data,
        &RingConfig { k: 2, partition_source: PartitionSource::RustFallback, ..Default::default() },
    )
    .unwrap();
    assert!(with_xla.telemetry.partition_source.starts_with("xla"));
    // f32 similarity can reorder a few clustering merges; final scores
    // must land within a small relative band.
    let gap = (with_xla.score - with_rust.score).abs() / with_rust.score.abs();
    assert!(gap < 0.02, "xla {} vs rust {}", with_xla.score, with_rust.score);
}
