//! End-to-end integration: generate → sample → (partition) → learn →
//! evaluate, across all algorithms, exercising the public API exactly
//! the way the examples and the CLI do.

use std::sync::Arc;

use cges::bn::{forward_sample, generate, parse_bif, write_bif, NetGenConfig};
use cges::coordinator::{cges, RingConfig, RingMode};
use cges::data::{read_csv, write_csv};
use cges::graph::Dag;
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::{evaluate, smhd};
use cges::score::BdeuScorer;

fn workload(nodes: usize, edges: usize, rows: usize, seed: u64) -> (cges::bn::DiscreteBn, Arc<cges::data::Dataset>) {
    let bn = generate(&NetGenConfig { nodes, edges, ..Default::default() }, seed);
    let data = Arc::new(forward_sample(&bn, rows, seed * 31 + 1));
    (bn, data)
}

#[test]
fn all_algorithms_recover_structure() {
    let (bn, data) = workload(24, 32, 3000, 5);
    let sc = BdeuScorer::new(data.clone(), 10.0);
    let empty_score = sc.score_dag(&Dag::new(24));

    let g = ges(&sc, &Dag::new(24), &GesConfig::default());
    let f = {
        let sc = BdeuScorer::new(data.clone(), 10.0);
        fges(&sc, &Dag::new(24), &FgesConfig::default())
    };
    let ring = cges(data.clone(), &RingConfig { k: 2, ..Default::default() }).unwrap();
    let ring4 = cges(data.clone(), &RingConfig { k: 4, limit_inserts: false, ..Default::default() }).unwrap();

    for (name, dag, score) in [
        ("ges", &g.dag, g.score),
        ("fges", &f.dag, f.score),
        ("cges-l2", &ring.dag, ring.score),
        ("cges4", &ring4.dag, ring4.score),
    ] {
        assert!(score > empty_score, "{name} must beat the empty graph");
        let rep = evaluate(dag, &bn.dag, &sc);
        assert!(rep.f1 > 0.6, "{name}: skeleton F1 {:.3} too low", rep.f1);
        assert!(dag.is_acyclic(), "{name}: produced a cyclic graph");
    }

    // GES (full T-search) should not lose to fGES.
    assert!(g.score >= f.score - 1e-9);
}

#[test]
fn ring_quality_close_to_ges() {
    let (_bn, data) = workload(30, 42, 2500, 9);
    let sc = BdeuScorer::new(data.clone(), 10.0);
    let g = ges(&sc, &Dag::new(30), &GesConfig::default());
    let ring = cges(data, &RingConfig { k: 4, ..Default::default() }).unwrap();
    // The paper's observation: cGES trades a small amount of BDeu for
    // speed; on small instances the fine-tune phase usually closes the
    // gap entirely.
    let rel_gap = (g.score - ring.score) / g.score.abs();
    assert!(rel_gap.abs() < 0.02, "ring {} vs ges {} (gap {rel_gap})", ring.score, g.score);
}

#[test]
fn file_roundtrip_pipeline() {
    // The CLI's workflow through the library API: bif + csv round trips
    // feeding a learner.
    let (bn, data) = workload(12, 16, 800, 21);
    let dir = std::env::temp_dir();
    let bif = dir.join("cges_it_net.bif");
    let csv = dir.join("cges_it_data.csv");
    write_bif(&bn, &bif).unwrap();
    write_csv(&data, &csv).unwrap();

    let bn2 = cges::bn::read_bif(&bif).unwrap();
    let data2 = Arc::new(read_csv(&csv).unwrap());
    assert_eq!(bn2.n(), bn.n());
    assert_eq!(data2.n_rows(), data.n_rows());

    let sc = BdeuScorer::new(data2, 10.0);
    let r = ges(&sc, &Dag::new(12), &GesConfig::default());
    assert!(smhd(&r.dag, &bn2.dag) < 16, "learned structure too far from truth");
    std::fs::remove_file(&bif).ok();
    std::fs::remove_file(&csv).ok();
}

#[test]
fn bif_text_parses_tetrad_style() {
    // Regression guard on the grammar corner cases: multi-parent blocks
    // and table rows.
    let text = r#"
network n { }
variable A { type discrete [ 3 ] { a0, a1, a2 }; }
variable B { type discrete [ 2 ] { b0, b1 }; }
probability ( A ) { table 0.2, 0.5, 0.3; }
probability ( B | A ) {
  (a0) 0.9, 0.1;
  (a1) 0.4, 0.6;
  (a2) 0.5, 0.5;
}
"#;
    let bn = parse_bif(text).unwrap();
    assert_eq!(bn.cards, vec![3, 2]);
    let b = bn.names.iter().position(|n| n == "B").unwrap();
    assert!((bn.cpts[b].row(1)[1] - 0.6).abs() < 1e-12);
    // Sample from it and make sure states respect cardinalities.
    let d = forward_sample(&bn, 500, 3);
    assert!(d.col(0).iter().all(|&s| s < 3));
    assert!(d.col(1).iter().all(|&s| s < 2));
}

/// Acceptance gate for the ring runtime: the same `cges()` call must
/// produce the identical `(dag, score)` on the deterministic barrier
/// scheduler, the pipelined in-process channel transport, and the
/// pipelined TCP-loopback wire transport — per-worker dataflow and the
/// convergence rule are mode-independent by construction.
#[test]
fn ring_transports_and_deterministic_mode_agree() {
    let (_bn, data) = workload(18, 24, 2000, 33);
    let base = RingConfig { k: 3, threads: 3, ..Default::default() };
    let det = cges(
        data.clone(),
        &RingConfig { mode: RingMode::Deterministic, ..base.clone() },
    )
    .unwrap();
    let chan =
        cges(data.clone(), &RingConfig { mode: RingMode::Channel, ..base.clone() }).unwrap();
    let tcp = cges(data, &RingConfig { mode: RingMode::Tcp, ..base }).unwrap();

    for (name, r) in [("channel", &chan), ("tcp", &tcp)] {
        assert_eq!(
            det.dag.edges(),
            r.dag.edges(),
            "{name} transport changed the learned structure"
        );
        assert!(
            (det.score - r.score).abs() < 1e-9,
            "{name} score {} vs deterministic {}",
            r.score,
            det.score
        );
        assert_eq!(det.rounds, r.rounds, "{name} counted different rounds");
    }
    assert_eq!(det.telemetry.transport, "deterministic");
    assert_eq!(chan.telemetry.transport, "channel");
    assert_eq!(tcp.telemetry.transport, "tcp");
    // The deterministic barrier never waits on a message.
    assert!(det.telemetry.records.iter().all(|rec| rec.wait_secs == 0.0));
}

#[test]
fn telemetry_records_every_round_and_worker() {
    let (_bn, data) = workload(16, 22, 1200, 13);
    let k = 3;
    let r = cges(data, &RingConfig { k, threads: 3, ..Default::default() }).unwrap();
    // Every round must have exactly k records.
    for round in 0..r.rounds {
        let cnt = r.telemetry.records.iter().filter(|rec| rec.round == round).count();
        assert_eq!(cnt, k, "round {round} has {cnt} records");
    }
    // Convergence trace is monotone non-decreasing in best score.
    let trace = r.telemetry.round_best_scores();
    let mut best = f64::NEG_INFINITY;
    let mut mono = Vec::new();
    for (_, s) in &trace {
        best = best.max(*s);
        mono.push(best);
    }
    for w in mono.windows(2) {
        assert!(w[1] >= w[0]);
    }
}
