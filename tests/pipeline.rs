//! End-to-end integration: generate → sample → (partition) → learn →
//! evaluate, across all algorithms, exercising the public API exactly
//! the way the examples and the CLI do.

use std::sync::Arc;

use cges::bn::{forward_sample, generate, parse_bif, write_bif, NetGenConfig};
use cges::coordinator::{cges, RingConfig, RingMode};
use cges::data::{read_csv, write_csv};
use cges::graph::Dag;
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::{evaluate, smhd};
use cges::score::BdeuScorer;

fn workload(nodes: usize, edges: usize, rows: usize, seed: u64) -> (cges::bn::DiscreteBn, Arc<cges::data::Dataset>) {
    let bn = generate(&NetGenConfig { nodes, edges, ..Default::default() }, seed);
    let data = Arc::new(forward_sample(&bn, rows, seed * 31 + 1));
    (bn, data)
}

#[test]
fn all_algorithms_recover_structure() {
    let (bn, data) = workload(24, 32, 3000, 5);
    let sc = BdeuScorer::new(data.clone(), 10.0);
    let empty_score = sc.score_dag(&Dag::new(24));

    let g = ges(&sc, &Dag::new(24), &GesConfig::default());
    let f = {
        let sc = BdeuScorer::new(data.clone(), 10.0);
        fges(&sc, &Dag::new(24), &FgesConfig::default())
    };
    let ring = cges(data.clone(), &RingConfig { k: 2, ..Default::default() }).unwrap();
    let ring4 = cges(data.clone(), &RingConfig { k: 4, limit_inserts: false, ..Default::default() }).unwrap();

    for (name, dag, score) in [
        ("ges", &g.dag, g.score),
        ("fges", &f.dag, f.score),
        ("cges-l2", &ring.dag, ring.score),
        ("cges4", &ring4.dag, ring4.score),
    ] {
        assert!(score > empty_score, "{name} must beat the empty graph");
        let rep = evaluate(dag, &bn.dag, &sc);
        assert!(rep.f1 > 0.6, "{name}: skeleton F1 {:.3} too low", rep.f1);
        assert!(dag.is_acyclic(), "{name}: produced a cyclic graph");
    }

    // GES (full T-search) should not lose to fGES.
    assert!(g.score >= f.score - 1e-9);
}

#[test]
fn ring_quality_close_to_ges() {
    let (_bn, data) = workload(30, 42, 2500, 9);
    let sc = BdeuScorer::new(data.clone(), 10.0);
    let g = ges(&sc, &Dag::new(30), &GesConfig::default());
    let ring = cges(data, &RingConfig { k: 4, ..Default::default() }).unwrap();
    // The paper's observation: cGES trades a small amount of BDeu for
    // speed; on small instances the fine-tune phase usually closes the
    // gap entirely.
    let rel_gap = (g.score - ring.score) / g.score.abs();
    assert!(rel_gap.abs() < 0.02, "ring {} vs ges {} (gap {rel_gap})", ring.score, g.score);
}

#[test]
fn file_roundtrip_pipeline() {
    // The CLI's workflow through the library API: bif + csv round trips
    // feeding a learner.
    let (bn, data) = workload(12, 16, 800, 21);
    let dir = std::env::temp_dir();
    let bif = dir.join("cges_it_net.bif");
    let csv = dir.join("cges_it_data.csv");
    write_bif(&bn, &bif).unwrap();
    write_csv(&data, &csv).unwrap();

    let bn2 = cges::bn::read_bif(&bif).unwrap();
    let data2 = Arc::new(read_csv(&csv).unwrap());
    assert_eq!(bn2.n(), bn.n());
    assert_eq!(data2.n_rows(), data.n_rows());

    let sc = BdeuScorer::new(data2, 10.0);
    let r = ges(&sc, &Dag::new(12), &GesConfig::default());
    assert!(smhd(&r.dag, &bn2.dag) < 16, "learned structure too far from truth");
    std::fs::remove_file(&bif).ok();
    std::fs::remove_file(&csv).ok();
}

#[test]
fn bif_text_parses_tetrad_style() {
    // Regression guard on the grammar corner cases: multi-parent blocks
    // and table rows.
    let text = r#"
network n { }
variable A { type discrete [ 3 ] { a0, a1, a2 }; }
variable B { type discrete [ 2 ] { b0, b1 }; }
probability ( A ) { table 0.2, 0.5, 0.3; }
probability ( B | A ) {
  (a0) 0.9, 0.1;
  (a1) 0.4, 0.6;
  (a2) 0.5, 0.5;
}
"#;
    let bn = parse_bif(text).unwrap();
    assert_eq!(bn.cards, vec![3, 2]);
    let b = bn.names.iter().position(|n| n == "B").unwrap();
    assert!((bn.cpts[b].row(1)[1] - 0.6).abs() < 1e-12);
    // Sample from it and make sure states respect cardinalities.
    let d = forward_sample(&bn, 500, 3);
    assert!(d.col(0).iter().all(|&s| s < 3));
    assert!(d.col(1).iter().all(|&s| s < 2));
}

/// Acceptance gate for the ring runtime: the same `cges()` call must
/// produce the identical `(dag, score)` on the deterministic barrier
/// scheduler, the pipelined in-process channel transport, and the
/// pipelined TCP-loopback wire transport — per-worker dataflow and the
/// convergence rule are mode-independent by construction.
#[test]
fn ring_transports_and_deterministic_mode_agree() {
    let (_bn, data) = workload(18, 24, 2000, 33);
    let base = RingConfig { k: 3, threads: 3, ..Default::default() };
    let det = cges(
        data.clone(),
        &RingConfig { mode: RingMode::Deterministic, ..base.clone() },
    )
    .unwrap();
    let chan =
        cges(data.clone(), &RingConfig { mode: RingMode::Channel, ..base.clone() }).unwrap();
    let tcp = cges(data, &RingConfig { mode: RingMode::Tcp, ..base }).unwrap();

    for (name, r) in [("channel", &chan), ("tcp", &tcp)] {
        assert_eq!(
            det.dag.edges(),
            r.dag.edges(),
            "{name} transport changed the learned structure"
        );
        assert!(
            (det.score - r.score).abs() < 1e-9,
            "{name} score {} vs deterministic {}",
            r.score,
            det.score
        );
        assert_eq!(det.rounds, r.rounds, "{name} counted different rounds");
    }
    assert_eq!(det.telemetry.transport, "deterministic");
    assert_eq!(chan.telemetry.transport, "channel");
    assert_eq!(tcp.telemetry.transport, "tcp");
    // The deterministic barrier never waits on a message.
    assert!(det.telemetry.records.iter().all(|rec| rec.wait_secs == 0.0));
}

/// Observability round-trip over a real ring run (satellite of the
/// obs PR): a live tracer's Chrome export must parse back as JSON
/// with strictly matched B/E pairs per lane, monotone timestamps, and
/// worker lanes drawn from the telemetry's own worker set; the
/// metrics registry must have picked up the live counters. A disabled
/// tracer on the same workload emits zero spans and zero bytes.
#[test]
fn ring_trace_roundtrip_chrome_events() {
    use cges::infer::json::Json;
    use cges::obs::{Registry, Tracer, COORDINATOR_TID};
    use std::collections::{BTreeMap, BTreeSet};

    let (_bn, data) = workload(14, 18, 900, 7);
    let tracer = Tracer::new(true);
    let registry = Registry::new();
    let r = cges(
        data.clone(),
        &RingConfig {
            k: 3,
            threads: 3,
            registry: Some(registry.clone()),
            tracer: tracer.clone(),
            ..Default::default()
        },
    )
    .unwrap();

    // Live counters landed in the registry: hop metrics exported by
    // the telemetry, score-cache counters bound by the scorer.
    assert!(registry.counter_value("ring.hops").unwrap_or(0) >= 3, "hop counter missing");
    let cache_traffic = registry.counter_value("score_cache.hits").unwrap_or(0)
        + registry.counter_value("score_cache.misses").unwrap_or(0);
    assert!(cache_traffic > 0, "bound score-cache counters saw no traffic");

    // Ring-category span lanes are exactly telemetry workers (the
    // coordinator records its stage spans in its own lane).
    let spans = tracer.spans();
    assert!(!spans.is_empty(), "enabled tracer recorded nothing");
    let telemetry_workers: BTreeSet<u32> =
        r.telemetry.timelines().iter().map(|t| t.worker as u32).collect();
    for sp in &spans {
        if sp.cat == "ring" {
            assert!(
                telemetry_workers.contains(&sp.tid),
                "ring span '{}' on unknown worker lane {}",
                sp.name,
                sp.tid
            );
        } else if sp.cat == "stage" {
            assert_eq!(sp.tid, COORDINATOR_TID, "stage span off the coordinator lane");
        }
    }
    assert!(spans.iter().any(|s| s.cat == "ring" && s.name == "ges"), "no ges spans");
    assert!(spans.iter().any(|s| s.cat == "ring" && s.name == "fuse"), "no fuse spans");
    assert!(spans.iter().any(|s| s.cat == "stage" && s.name == "learning"), "no stage span");

    // Chrome export: valid JSON, strict B/E pairing with monotone
    // timestamps inside every lane.
    let text = tracer.chrome_json();
    let events = Json::parse(&text).expect("chrome trace must parse");
    let events = events.as_array().expect("chrome trace is an event array");
    assert!(!events.is_empty());
    let mut stacks: BTreeMap<u64, Vec<(String, f64)>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for ev in events {
        let tid = ev.get("tid").and_then(Json::as_f64).expect("tid") as u64;
        let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
        let name = ev.get("name").and_then(Json::as_str).expect("name").to_string();
        let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
        let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
        assert!(ts >= *prev, "lane {tid}: timestamp went backwards ({ts} < {prev})");
        *prev = ts;
        match ph {
            "B" => stacks.entry(tid).or_default().push((name, ts)),
            "E" => {
                let (open, begin_ts) = stacks
                    .get_mut(&tid)
                    .and_then(Vec::pop)
                    .unwrap_or_else(|| panic!("lane {tid}: E '{name}' without matching B"));
                assert_eq!(open, name, "lane {tid}: mismatched B/E nesting");
                assert!(ts >= begin_ts, "lane {tid}: span '{name}' ends before it begins");
            }
            other => panic!("unexpected phase '{other}'"),
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "lane {tid}: {} unclosed spans", stack.len());
    }

    // The reconstructed telemetry trace covers the same worker lanes.
    let tele_spans = r.telemetry.to_spans();
    let tele_lanes: BTreeSet<u32> = tele_spans.iter().map(|s| s.tid).collect();
    assert_eq!(tele_lanes, telemetry_workers);

    // Disabled tracer: same run shape, zero spans, zero bytes.
    let off = Tracer::disabled();
    cges(data, &RingConfig { k: 3, threads: 3, tracer: off.clone(), ..Default::default() })
        .unwrap();
    assert_eq!(off.span_count(), 0, "disabled tracer recorded spans");
    assert!(off.chrome_json().is_empty(), "disabled tracer emitted bytes");
}

/// Acceptance gate for the distributed obs wire (tentpole of the obs
/// PR): a ring run over the real TCP wire transport with
/// `distributed_obs` on must deliver every worker's spans and metric
/// deltas to the coordinator — one Chrome-parseable timeline with one
/// lane per worker (strict B/E pairing, monotone clock-aligned
/// timestamps per lane) and one merged registry carrying
/// `worker<k>.*` series for every worker — while leaving the learned
/// structure identical to a run with the capability off.
#[test]
fn distributed_obs_tcp_ring_merges_one_timeline() {
    use cges::infer::json::Json;
    use cges::obs::{Registry, Tracer, COORDINATOR_TID};
    use std::collections::{BTreeMap, BTreeSet};

    let (_bn, data) = workload(14, 18, 900, 7);
    let k = 3;
    let tracer = Tracer::new(true);
    let registry = Registry::new();
    let obs = cges(
        data.clone(),
        &RingConfig {
            k,
            threads: k,
            mode: RingMode::Tcp,
            distributed_obs: true,
            registry: Some(registry.clone()),
            tracer: tracer.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    let plain = cges(
        data,
        &RingConfig { k, threads: k, mode: RingMode::Tcp, ..Default::default() },
    )
    .unwrap();

    // The capability must not perturb the learning outcome.
    assert_eq!(obs.dag.edges(), plain.dag.edges(), "obs wire changed the structure");
    assert_eq!(obs.score.to_bits(), plain.score.to_bits(), "obs wire changed the score");

    // Merged registry: every worker shipped its deltas; the prefixed
    // hop counter sums to the global one telemetry exports.
    let mut shipped_hops = 0;
    for w in 0..k {
        let hops = registry.counter_value(&format!("worker{w}.ring.hops")).unwrap_or(0);
        assert!(hops >= 1, "worker{w}: no hops shipped over the obs wire");
        shipped_hops += hops;
        assert!(
            registry.hist(&format!("worker{w}.ring.ges_ns")).inner().count() >= 1,
            "worker{w}: no ges latency shipped"
        );
    }
    assert_eq!(
        shipped_hops,
        registry.counter_value("ring.hops").unwrap_or(0),
        "shipped per-worker hops disagree with the telemetry total"
    );

    // One timeline: the coordinator tracer now holds every worker's
    // ring spans (clock-rebased) next to its own stage spans.
    let text = tracer.chrome_json();
    let events = Json::parse(&text).expect("merged chrome trace must parse");
    let events = events.as_array().expect("chrome trace is an event array");
    let mut ring_lanes: BTreeSet<u64> = BTreeSet::new();
    let mut stacks: BTreeMap<u64, Vec<(String, f64)>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for ev in events {
        let tid = ev.get("tid").and_then(Json::as_f64).expect("tid") as u64;
        let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
        let name = ev.get("name").and_then(Json::as_str).expect("name").to_string();
        let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
        let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("");
        if cat == "ring" {
            ring_lanes.insert(tid);
        } else if cat == "stage" {
            assert_eq!(tid, COORDINATOR_TID as u64, "stage span off the coordinator lane");
        }
        let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
        assert!(ts >= *prev, "lane {tid}: timestamp went backwards ({ts} < {prev})");
        *prev = ts;
        match ph {
            "B" => stacks.entry(tid).or_default().push((name, ts)),
            "E" => {
                let (open, begin_ts) = stacks
                    .get_mut(&tid)
                    .and_then(Vec::pop)
                    .unwrap_or_else(|| panic!("lane {tid}: E '{name}' without matching B"));
                assert_eq!(open, name, "lane {tid}: mismatched B/E nesting");
                assert!(ts >= begin_ts, "lane {tid}: span '{name}' ends before it begins");
            }
            other => panic!("unexpected phase '{other}'"),
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "lane {tid}: {} unclosed spans", stack.len());
    }
    let expected: BTreeSet<u64> = (0..k as u64).collect();
    assert_eq!(ring_lanes, expected, "every worker must own a ring-span lane");
}

#[test]
fn telemetry_records_every_round_and_worker() {
    let (_bn, data) = workload(16, 22, 1200, 13);
    let k = 3;
    let r = cges(data, &RingConfig { k, threads: 3, ..Default::default() }).unwrap();
    // Every round must have exactly k records.
    for round in 0..r.rounds {
        let cnt = r.telemetry.records.iter().filter(|rec| rec.round == round).count();
        assert_eq!(cnt, k, "round {round} has {cnt} records");
    }
    // Convergence trace is monotone non-decreasing in best score.
    let trace = r.telemetry.round_best_scores();
    let mut best = f64::NEG_INFINITY;
    let mut mono = Vec::new();
    for (_, s) in &trace {
        best = best.max(*s);
        mono.push(best);
    }
    for w in mono.windows(2) {
        assert!(w[1] >= w[0]);
    }
}
