//! Fault-tolerance acceptance tests for the ring runtime: scripted
//! chaos (kills, delays, corruption, duplication) through the
//! [`FaultPlan`] harness, and the pin that a disabled harness leaves
//! runs bit-identical to the legacy behavior.

use std::sync::Arc;
use std::time::Duration;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::coordinator::fault::recv_with_policy;
use cges::coordinator::{
    cges, run_ring, FaultPlan, FaultPolicy, FaultStats, ModelMsg, RingConfig, RingFault,
    RingMessage, RingMode, RingRunOptions, RingTransport, WireTransport,
};
use cges::graph::Dag;
use cges::learn::{GesConfig, RingWorker};
use cges::score::BdeuScorer;

fn workload(nodes: usize, edges: usize, rows: usize, seed: u64) -> Arc<cges::data::Dataset> {
    let bn = generate(&NetGenConfig { nodes, edges, ..Default::default() }, seed);
    Arc::new(forward_sample(&bn, rows, seed * 31 + 1))
}

/// Acceptance gate for ring healing: a 4-worker TCP ring whose worker
/// 2 is scripted to panic mid-run (at its second model send) must
/// still complete — the dead worker's thread relays messages past it
/// and its edge subset moves to a surviving worker — with a BDeu score
/// close to the fault-free run's.
#[test]
fn tcp_ring_survives_mid_round_worker_kill() {
    let data = workload(18, 24, 1500, 11);
    let base = RingConfig { k: 4, threads: 4, mode: RingMode::Tcp, ..Default::default() };
    let clean = cges(data.clone(), &base).unwrap();

    let chaos = cges(
        data,
        &RingConfig {
            fault_plan: Some(FaultPlan::parse("kill:w2@1").unwrap()),
            // Generous deadline: pure CI-hang safety — healing keeps
            // messages flowing, so it should never fire.
            fault_policy: FaultPolicy {
                recv_timeout: Some(Duration::from_secs(10)),
                ..Default::default()
            },
            ..base
        },
    )
    .unwrap();

    let f = &chaos.telemetry.faults;
    assert_eq!(f.deaths, 1, "exactly one scripted death: {f:?}");
    assert_eq!(f.healed, 1, "the death must be healed: {f:?}");
    assert!(chaos.score.is_finite());
    assert!(chaos.rounds >= 1);
    // Quality bound: losing one worker mid-run (its subset is
    // redistributed, and stage-3 fine-tuning is unrestricted) must not
    // collapse the score.
    let rel_gap = (clean.score - chaos.score) / clean.score.abs();
    assert!(
        rel_gap.abs() < 0.05,
        "healed run strayed too far: {} vs fault-free {} (gap {rel_gap})",
        chaos.score,
        clean.score
    );
}

/// Straggler policy: a scripted 800ms send delay against a 100ms recv
/// deadline forces the successor to skip the late round and step on
/// its own model; once the delay passes, the late worker's messages
/// are consumed again and the ring finishes with every worker
/// contributing.
#[test]
fn delayed_straggler_is_skipped_then_rejoins() {
    let data = workload(16, 22, 1200, 23);
    let r = cges(
        data,
        &RingConfig {
            k: 3,
            threads: 3,
            mode: RingMode::Channel,
            fault_plan: Some(FaultPlan::parse("delay:w1@1:800ms").unwrap()),
            fault_policy: FaultPolicy {
                recv_timeout: Some(Duration::from_millis(100)),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let f = &r.telemetry.faults;
    assert!(f.timeouts >= 1, "the 800ms delay must trip the 100ms deadline: {f:?}");
    assert!(f.skips >= 1, "a tripped deadline skips the round: {f:?}");
    assert_eq!(f.deaths, 0, "a straggler is not a death: {f:?}");
    assert!(r.score.is_finite());
    // Rejoin: the workers downstream of the sleeper keep producing
    // rounds during the incident, and the delayed worker itself still
    // lands its round-1 hop once the delay passes.
    for w in [0, 2] {
        assert!(
            r.telemetry.records.iter().any(|rec| rec.worker == w && rec.round >= 2),
            "worker {w} has no post-incident records"
        );
    }
    assert!(
        r.telemetry.records.iter().any(|rec| rec.worker == 1 && rec.round >= 1),
        "the delayed worker never completed its late round"
    );
}

/// A corrupted wire frame is consumed, logged, and ridden out: the
/// receiver retries and fuses the predecessor's next clean frame, and
/// the run completes.
#[test]
fn corrupted_wire_frame_is_retried() {
    let data = workload(14, 18, 1000, 31);
    let r = cges(
        data,
        &RingConfig {
            k: 3,
            threads: 3,
            mode: RingMode::Tcp,
            fault_plan: Some(FaultPlan::parse("corrupt:w0@1").unwrap()),
            ..Default::default()
        },
    )
    .unwrap();
    let f = &r.telemetry.faults;
    assert!(f.decode >= 1, "the mangled frame must surface as a decode fault: {f:?}");
    assert!(f.retries >= 1, "the decode fault must be retried: {f:?}");
    assert_eq!(f.deaths, 0, "{f:?}");
    assert!(r.score.is_finite());
}

/// Past the retry budget, corruption surfaces as the typed
/// [`RingFault::Decode`] — exercised at the transport level over a
/// real wire link pair.
#[test]
fn decode_faults_surface_typed_after_retry_budget() {
    let links = WireTransport.connect(2).unwrap();
    let mut it = links.into_iter();
    let mut w0 = it.next().unwrap();
    let mut w1 = it.next().unwrap();
    let msg = || {
        RingMessage::Model(ModelMsg {
            from: 0,
            round: 0,
            score: -1.0,
            dag: Dag::new(3),
            token: Default::default(),
            bundle: None,
            obs: Vec::new(),
        })
    };
    // Two corrupt frames against a budget of one retry.
    w0.tx.send_corrupt(msg()).unwrap();
    w0.tx.send_corrupt(msg()).unwrap();
    let policy = FaultPolicy {
        recv_timeout: Some(Duration::from_secs(5)),
        max_retries: 1,
        backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let stats = FaultStats::default();
    let err = recv_with_policy(w1.rx.as_mut(), &policy, &stats, 1).unwrap_err();
    assert!(matches!(err, RingFault::Decode { .. }), "{err}");
    let s = stats.snapshot();
    assert_eq!(s.decode, 2, "{s:?}");
    assert_eq!(s.retries, 1, "{s:?}");
}

/// A duplicated frame is discarded by the receiver's (from, round)
/// filter — and because the duplicate carries no new information, the
/// learned result is identical to the clean run's.
#[test]
fn duplicated_frames_are_discarded() {
    let data = workload(14, 18, 1000, 43);
    let base = RingConfig { k: 3, threads: 3, mode: RingMode::Channel, ..Default::default() };
    let clean = cges(data.clone(), &base).unwrap();
    let dup = cges(
        data,
        &RingConfig { fault_plan: Some(FaultPlan::parse("dup:w0@0").unwrap()), ..base },
    )
    .unwrap();
    assert!(dup.telemetry.faults.duplicates >= 1, "{:?}", dup.telemetry.faults);
    assert_eq!(clean.dag.edges(), dup.dag.edges(), "a discarded duplicate changed the result");
    assert_eq!(clean.score.to_bits(), dup.score.to_bits());
}

/// The byte/bit-identity pin: arming the fault machinery (deadlines,
/// retry budget, healing) without any scripted fault must leave the
/// learned structure, score bits, and round count identical to a run
/// with the machinery at rest — on both pipelined transports.
#[test]
fn faults_off_runs_are_bit_identical() {
    let data = workload(16, 22, 1200, 53);
    for mode in [RingMode::Channel, RingMode::Tcp] {
        let base = RingConfig { k: 3, threads: 3, mode, ..Default::default() };
        let plain = cges(data.clone(), &base).unwrap();
        let armed = cges(
            data.clone(),
            &RingConfig {
                fault_policy: FaultPolicy {
                    recv_timeout: Some(Duration::from_secs(30)),
                    ..Default::default()
                },
                fault_plan: Some(FaultPlan::parse("").unwrap()), // empty plan
                ..base
            },
        )
        .unwrap();
        assert_eq!(
            plain.dag.edges(),
            armed.dag.edges(),
            "{mode:?}: armed fault machinery changed the structure"
        );
        assert_eq!(
            plain.score.to_bits(),
            armed.score.to_bits(),
            "{mode:?}: armed fault machinery changed the score bits"
        );
        assert_eq!(plain.rounds, armed.rounds, "{mode:?}: round counts diverged");
        assert!(!armed.telemetry.faults.any(), "{:?}", armed.telemetry.faults);
    }
}

/// With healing disabled, a worker death is a run failure — surfaced
/// as [`RingFault::WorkerPanicked`] (asserted through its rendered
/// message: the vendored `anyhow` drop-in stores message chains, not
/// downcastable values), not a hang and not a generic join panic.
#[test]
fn heal_off_worker_death_fails_with_typed_fault() {
    let data = workload(12, 16, 800, 61);
    let scorer = BdeuScorer::new(data, 10.0);
    let workers: Vec<RingWorker> = (0..2)
        .map(|_| RingWorker::new(scorer.clone(), GesConfig { threads: 2, ..Default::default() }))
        .collect();
    let err = match run_ring(
        workers,
        &RingRunOptions {
            max_rounds: 8,
            mode: RingMode::Channel,
            policy: FaultPolicy { heal: false, ..Default::default() },
            plan: Some(FaultPlan::parse("kill:w1@0").unwrap()),
            ..Default::default()
        },
    ) {
        Ok(_) => panic!("a worker death with healing disabled must fail the run"),
        Err(e) => e,
    };
    let rendered = format!("{err:#}");
    assert!(
        rendered.contains("ring worker 1 panicked"),
        "expected a WorkerPanicked fault for worker 1, got: {rendered}"
    );
    assert!(
        rendered.contains("fault-plan kill"),
        "the panic payload (scripted kill) must be preserved: {rendered}"
    );
    // The typed value itself renders the same way — pin the two
    // surfaces together so the CLI message can't drift from the type.
    let typed = RingFault::WorkerPanicked {
        worker: 1,
        detail: "fault-plan kill: worker 1 at hop 0".to_string(),
    };
    assert_eq!(rendered, typed.to_string());
}
